//! Fleet-wide metric aggregation: merge per-shard `coordinator::Metrics`
//! snapshots into one fleet-level view.
//!
//! Percentiles are computed from the **merged histogram** — bucket counts
//! add across shards, so fleet p50/p95/p99 are quantiles of the combined
//! latency distribution. Averaging per-shard percentiles would understate
//! the tail whenever shards are imbalanced; the tests pin this down.

use crate::coordinator::metrics::{MetricsInner, RouteMetrics};
use crate::fleet::autoscale::LoadSample;
use crate::fleet::topology::ShardId;
use crate::trace::StageNs;
use crate::util::stats::LatencyHist;
use crate::util::tables::Table;

/// One shard's contribution to a fleet snapshot.
#[derive(Debug, Clone)]
pub struct ShardSnapshot {
    pub id: ShardId,
    pub metrics: MetricsInner,
}

/// Gateway-boundary admission counters (PR 7's shed/quarantine machinery),
/// folded into the fleet snapshot so the autoscaler and operators see them
/// next to the merged latency histograms instead of on a separate surface.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GatewayCounters {
    /// connections/hellos shed by the bounded accept queue
    pub shed_sessions: u64,
    /// requests refused by the per-session rate cap
    pub rate_limited: u64,
    /// sessions quarantined for exhausting a hostile-input budget
    pub quarantined_sessions: u64,
    /// frames dropped from already-quarantined sessions
    pub quarantine_drops: u64,
}

impl GatewayCounters {
    /// Fraction of admission attempts the gateway refused, in `[0, 1]` —
    /// the shed signal [`crate::fleet::autoscale`] scales up on.
    pub fn shed_rate(&self, forwarded_requests: u64) -> f64 {
        let refused = self.shed_sessions + self.rate_limited + self.quarantine_drops;
        let total = refused + forwarded_requests;
        if total == 0 {
            0.0
        } else {
            refused as f64 / total as f64
        }
    }

    /// Counters accumulated since `prev` was captured — saturating, so a
    /// `prev` that is not actually an earlier reading of the same gateway
    /// clamps at zero instead of underflowing.
    pub fn delta(&self, prev: &GatewayCounters) -> GatewayCounters {
        GatewayCounters {
            shed_sessions: self.shed_sessions.saturating_sub(prev.shed_sessions),
            rate_limited: self.rate_limited.saturating_sub(prev.rate_limited),
            quarantined_sessions: self
                .quarantined_sessions
                .saturating_sub(prev.quarantined_sessions),
            quarantine_drops: self.quarantine_drops.saturating_sub(prev.quarantine_drops),
        }
    }
}

/// Windowed load sampler for the autoscaler (DESIGN.md §11).
///
/// All fleet counters and histograms are *lifetime-cumulative*: the merged
/// queue histogram keeps every wait ever recorded and the gateway counters
/// never reset. Deriving [`LoadSample`]s straight from them is the bug this
/// type fixes — one historical shed storm pins `shed_rate > 0` forever and
/// the lifetime histogram dominates p95, so down-pressure can never
/// re-engage. A `LoadWindow` holds the previous sampling tick's cumulative
/// state and subtracts it, so each emitted sample describes only the
/// interval since the last call. An empty window (no new requests) reads
/// as idle: p95 0, shed rate 0.
#[derive(Debug, Clone, Default)]
pub struct LoadWindow {
    prev_queue: LatencyHist,
    prev_gateway: GatewayCounters,
    prev_requests: u64,
    prev_stages: StageNs,
}

impl LoadWindow {
    pub fn new() -> Self {
        LoadWindow::default()
    }

    /// Windowed sample from a full fleet snapshot (the threaded sampler's
    /// path): merges both routes' queue histograms, then subtracts the
    /// previous tick.
    pub fn sample(&mut self, snap: &FleetSnapshot, routable_shards: usize) -> LoadSample {
        let mut queue = snap.merged.full.queue_wait.clone();
        queue.merge(&snap.merged.split.queue_wait);
        self.sample_parts(&queue, snap.gateway, snap.total_requests(), routable_shards)
    }

    /// Windowed sample from already-merged cumulative inputs — the sim
    /// feeds its own queue histogram and gateway counters here without
    /// materialising a `FleetSnapshot` per tick.
    pub fn sample_parts(
        &mut self,
        queue: &LatencyHist,
        gateway: GatewayCounters,
        requests: u64,
        routable_shards: usize,
    ) -> LoadSample {
        let window_queue = queue.delta(&self.prev_queue);
        let window_gateway = gateway.delta(&self.prev_gateway);
        let window_requests = requests.saturating_sub(self.prev_requests);
        self.prev_queue = queue.clone();
        self.prev_gateway = gateway;
        self.prev_requests = requests;
        LoadSample {
            queue_p95_ns: window_queue.quantile_ns(0.95) as u64,
            shed_rate: window_gateway.shed_rate(window_requests),
            shards: routable_shards,
        }
    }

    /// Windowed per-stage attribution from cumulative span-stage totals
    /// (DESIGN.md §12): the delta since the previous call, so a scale
    /// verdict can cite the stage that dominated *this* interval rather
    /// than process history. Same saturating contract as the counter
    /// windows above.
    pub fn stage_window(&mut self, totals: &StageNs) -> StageNs {
        let window = totals.delta(&self.prev_stages);
        self.prev_stages = *totals;
        window
    }
}

/// Per-shard snapshots plus their merged fleet-level view.
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    pub shards: Vec<ShardSnapshot>,
    pub merged: MetricsInner,
    /// admission counters from the gateway in front of these shards
    /// (zeros when the fleet is consulted shard-direct)
    pub gateway: GatewayCounters,
}

/// Merge per-shard metric snapshots into a fleet snapshot.
pub fn aggregate(shards: impl IntoIterator<Item = (ShardId, MetricsInner)>) -> FleetSnapshot {
    let shards: Vec<ShardSnapshot> = shards
        .into_iter()
        .map(|(id, metrics)| ShardSnapshot { id, metrics })
        .collect();
    let mut merged = MetricsInner::default();
    for s in &shards {
        merged.merge(&s.metrics);
    }
    FleetSnapshot { shards, merged, gateway: GatewayCounters::default() }
}

fn route_cells(name: &str, rm: &RouteMetrics, elapsed: f64) -> Option<Vec<String>> {
    if rm.requests == 0 {
        return None;
    }
    let q = |p: f64| rm.service.quantile_ns(p) / 1e6;
    let thr = if elapsed > 0.0 { rm.requests as f64 / elapsed } else { 0.0 };
    Some(vec![
        name.to_string(),
        rm.requests.to_string(),
        format!("{:.1}", rm.mean_batch()),
        format!("{:.2}", q(0.5)),
        format!("{:.2}", q(0.95)),
        format!("{:.2}", q(0.99)),
        format!("{thr:.0}"),
    ])
}

impl FleetSnapshot {
    /// Attach the gateway's admission counters to this snapshot.
    pub fn with_gateway(mut self, gateway: GatewayCounters) -> Self {
        self.gateway = gateway;
        self
    }

    pub fn total_requests(&self) -> u64 {
        self.merged.full.requests + self.merged.split.requests
    }

    pub fn total_dropped(&self) -> u64 {
        self.merged.dropped
    }

    /// Fleet table: one row per (shard, route) plus merged fleet rows.
    /// `elapsed` is the measurement window in seconds (for throughput).
    pub fn table(&self, elapsed: f64) -> Table {
        let mut t = Table::new(
            "Fleet serving metrics (percentiles from the merged histogram)",
            &["source", "requests", "mean batch", "p50 (ms)", "p95 (ms)", "p99 (ms)", "req/s"],
        );
        for s in &self.shards {
            for (route, rm) in
                [("server-only", &s.metrics.full), ("split", &s.metrics.split)]
            {
                if let Some(cells) = route_cells(&format!("{} {route}", s.id), rm, elapsed) {
                    t.row(&cells);
                }
            }
        }
        for (route, rm) in [("server-only", &self.merged.full), ("split", &self.merged.split)] {
            if let Some(cells) = route_cells(&format!("fleet {route}"), rm, elapsed) {
                t.row(&cells);
            }
        }
        t
    }

    /// Gateway admission table: shed/rate-cap/quarantine counters plus the
    /// derived shed rate, rendered only when the gateway refused anything.
    pub fn gateway_table(&self) -> Option<Table> {
        let g = &self.gateway;
        if g.shed_sessions + g.rate_limited + g.quarantined_sessions + g.quarantine_drops == 0 {
            return None;
        }
        let mut t = Table::new(
            "Gateway admission (fleet-wide)",
            &["shed sessions", "rate limited", "quarantined", "quarantine drops", "shed rate"],
        );
        t.row(&[
            g.shed_sessions.to_string(),
            g.rate_limited.to_string(),
            g.quarantined_sessions.to_string(),
            g.quarantine_drops.to_string(),
            format!("{:.3}", g.shed_rate(self.total_requests())),
        ]);
        Some(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Route;
    use crate::coordinator::Metrics;
    use std::time::Duration;

    fn shard_with(lat_ms: &[u64]) -> MetricsInner {
        let m = Metrics::new();
        for &ms in lat_ms {
            m.record_batch(
                Route::Split,
                1,
                0,
                Duration::from_micros(20),
                &[Duration::from_millis(1)],
                Duration::from_millis(1),
                &[Duration::from_millis(ms)],
            );
        }
        m.snapshot()
    }

    /// Fleet percentiles must equal the quantiles of one histogram holding
    /// every shard's samples — not any combination of per-shard percentiles.
    #[test]
    fn fleet_percentiles_come_from_the_merged_histogram() {
        // shard 0: 95 fast requests; shard 1: 5 slow ones
        let fast: Vec<u64> = vec![10; 95];
        let slow: Vec<u64> = vec![500; 5];
        let snap = aggregate(vec![
            (ShardId(0), shard_with(&fast)),
            (ShardId(1), shard_with(&slow)),
        ]);

        // reference: a single recorder that saw all 100 requests
        let mut all = fast.clone();
        all.extend_from_slice(&slow);
        let reference = shard_with(&all);

        assert_eq!(snap.merged.split.requests, 100);
        for q in [0.5, 0.9, 0.95, 0.99] {
            assert_eq!(
                snap.merged.split.service.quantile_ns(q),
                reference.split.service.quantile_ns(q),
                "fleet q{q} != single-histogram q{q}"
            );
        }

        // the failure mode this design avoids: averaging per-shard p99s
        // (10ms and 500ms → 255ms) hides that the true fleet p99 is ~500ms
        let p99_fleet = snap.merged.split.service.quantile_ns(0.99) / 1e6;
        let p99_avg = (snap.shards[0].metrics.split.service.quantile_ns(0.99)
            + snap.shards[1].metrics.split.service.quantile_ns(0.99))
            / 2.0
            / 1e6;
        assert!(p99_fleet > 400.0, "fleet p99 lost the tail: {p99_fleet}ms");
        assert!(p99_avg < 300.0, "sanity: averaging should understate ({p99_avg}ms)");
    }

    #[test]
    fn aggregate_sums_counters_across_shards() {
        let snap = aggregate(vec![
            (ShardId(0), shard_with(&[10, 10])),
            (ShardId(1), shard_with(&[10])),
            (ShardId(2), shard_with(&[])),
        ]);
        assert_eq!(snap.total_requests(), 3);
        assert_eq!(snap.shards.len(), 3);
        assert_eq!(snap.merged.split.batches, 3);
        assert_eq!(snap.merged.full.requests, 0);
    }

    #[test]
    fn gateway_counters_fold_into_the_snapshot_and_drive_the_shed_rate() {
        let snap = aggregate(vec![(ShardId(0), shard_with(&[10; 6]))]).with_gateway(
            GatewayCounters {
                shed_sessions: 2,
                rate_limited: 1,
                quarantined_sessions: 1,
                quarantine_drops: 1,
            },
        );
        // 4 refusals (shed + rate-capped + quarantine drops) over 4 + 6
        // forwarded requests; the quarantined-session count is a session
        // gauge, not an admission attempt
        let rate = snap.gateway.shed_rate(snap.total_requests());
        assert!((rate - 0.4).abs() < 1e-9, "shed rate {rate}");
        let t = snap.gateway_table().expect("refusals must render");
        let md = t.to_markdown();
        assert!(md.contains("0.400"), "{md}");
        // a clean gateway renders nothing and sheds nothing
        let clean = aggregate(vec![(ShardId(0), shard_with(&[10]))]);
        assert_eq!(clean.gateway, GatewayCounters::default());
        assert_eq!(clean.gateway.shed_rate(clean.total_requests()), 0.0);
        assert!(clean.gateway_table().is_none());
    }

    #[test]
    fn gateway_counter_delta_is_saturating_and_windowed() {
        let prev = GatewayCounters {
            shed_sessions: 5,
            rate_limited: 2,
            quarantined_sessions: 1,
            quarantine_drops: 0,
        };
        let now = GatewayCounters {
            shed_sessions: 9,
            rate_limited: 2,
            quarantined_sessions: 1,
            quarantine_drops: 3,
        };
        let d = now.delta(&prev);
        assert_eq!(
            d,
            GatewayCounters {
                shed_sessions: 4,
                rate_limited: 0,
                quarantined_sessions: 0,
                quarantine_drops: 3,
            }
        );
        // a non-prefix prev clamps to zero instead of wrapping
        assert_eq!(prev.delta(&now), GatewayCounters::default());
    }

    #[test]
    fn load_window_samples_reflect_only_the_observation_window() {
        let mut w = LoadWindow::new();
        // first window: 6 requests (1 ms queue wait each) and 6 sheds
        let snap = aggregate(vec![
            (ShardId(0), shard_with(&[10; 3])),
            (ShardId(1), shard_with(&[10; 3])),
        ])
        .with_gateway(GatewayCounters { shed_sessions: 6, ..GatewayCounters::default() });
        let s = w.sample(&snap, 2);
        assert_eq!(s.shards, 2);
        assert!((s.shed_rate - 0.5).abs() < 1e-9, "6 sheds vs 6 requests: {}", s.shed_rate);
        // the p95 must come from the merged queue histogram, not read zero
        assert!(s.queue_p95_ns > 0);
        // second window: 6 more clean requests, no new sheds — the window
        // must read shed-free even though the cumulative counter still
        // says 6
        let snap2 = aggregate(vec![
            (ShardId(0), shard_with(&[10; 6])),
            (ShardId(1), shard_with(&[10; 6])),
        ])
        .with_gateway(GatewayCounters { shed_sessions: 6, ..GatewayCounters::default() });
        let s2 = w.sample(&snap2, 2);
        assert_eq!(s2.shed_rate, 0.0, "cumulative sheds leaked into the window");
        assert!(s2.queue_p95_ns > 0, "the window's own queue waits must register");
        // third window: nothing happened at all — reads idle
        let s3 = w.sample(&snap2, 2);
        assert_eq!(s3.queue_p95_ns, 0);
        assert_eq!(s3.shed_rate, 0.0);
    }

    /// Regression (ISSUE 9): one historical shed event must not pin the
    /// shed rate above zero forever. The pre-fix cumulative
    /// `load_sample` computed `shed_rate(total_requests())` over process
    /// lifetime, so after the storm below every later sample still read
    /// `shed_rate ≈ 0.87` and `queue_p95 ≈ 1 ms` — both above the
    /// down-pressure gates — and the autoscaler could never scale back
    /// down. Windowed sampling must re-engage it.
    #[test]
    fn scale_down_re_engages_after_a_historical_shed_event() {
        use crate::fleet::autoscale::{AutoscaleConfig, Autoscaler, ScaleAction};
        let mut scaler = Autoscaler::new(AutoscaleConfig {
            min_shards: 1,
            max_shards: 4,
            queue_high_ns: 5_000_000,
            queue_low_ns: 500_000,
            shed_high: 0.05,
            confirm: 2,
            cooldown: 1.0,
            ..AutoscaleConfig::default()
        });
        let mut w = LoadWindow::new();
        // the storm: 6 requests forwarded, 40 admission attempts shed
        let storm = aggregate(vec![(ShardId(0), shard_with(&[10; 6]))])
            .with_gateway(GatewayCounters { shed_sessions: 40, ..GatewayCounters::default() });
        let s = w.sample(&storm, 2);
        assert!(s.shed_rate > 0.5, "the storm window must read hot: {}", s.shed_rate);
        assert_eq!(scaler.observe(0.0, s), ScaleAction::Hold);
        // the storm ends. Cumulative counters stop moving but never reset;
        // every subsequent window must read idle and scale-down must fire
        // once the confirmation streak completes.
        let mut saw_down = false;
        for i in 1..=4u32 {
            let s = w.sample(&storm, 2);
            assert_eq!(s.shed_rate, 0.0, "historical shed leaked into window {i}");
            assert_eq!(s.queue_p95_ns, 0, "historical queue wait leaked into window {i}");
            if scaler.observe(f64::from(i) * 2.0, s) == ScaleAction::ScaleDown {
                saw_down = true;
            }
        }
        assert!(saw_down, "down-pressure never re-engaged after a past shed event");
    }

    #[test]
    fn table_renders_shard_and_fleet_rows() {
        let snap = aggregate(vec![
            (ShardId(0), shard_with(&[10; 4])),
            (ShardId(1), shard_with(&[20; 4])),
        ]);
        let t = snap.table(1.0);
        // 2 shard split rows + 1 fleet split row (no full traffic)
        assert_eq!(t.n_rows(), 3);
        let md = t.to_markdown();
        assert!(md.contains("fleet split"), "{md}");
        assert!(md.contains("shard-0 split"), "{md}");
    }

    /// The table's derived columns must actually be the arithmetic they
    /// claim: req/s is requests over the elapsed window, percentiles are
    /// read off the merged service histogram, and a zero-length window
    /// renders a throughput of 0 instead of dividing by zero.
    #[test]
    fn table_column_math_holds_up() {
        let snap = aggregate(vec![(ShardId(0), shard_with(&[10; 8]))]);
        // 8 requests over a 4 s window -> 2 req/s, printed without decimals
        let md = snap.table(4.0).to_markdown();
        let fleet_row = md.lines().find(|l| l.contains("fleet split")).expect("fleet row");
        let cells: Vec<&str> = fleet_row.split('|').map(str::trim).collect();
        let requests: f64 = cells[2].parse().expect("requests cell");
        let req_s: f64 = cells[7].parse().expect("req/s cell");
        assert_eq!(requests, 8.0, "{fleet_row}");
        assert_eq!(req_s, (requests / 4.0).round(), "{fleet_row}");
        // every service sample was 10 ms, so all three percentiles print
        // the same value the histogram reports, in milliseconds at 2 dp
        let p50 = format!("{:.2}", snap.merged.split.service.quantile_ns(0.5) / 1e6);
        for col in [4, 5, 6] {
            assert_eq!(cells[col], p50, "{fleet_row}");
        }
        // zero elapsed must not divide by zero
        let md0 = snap.table(0.0).to_markdown();
        let row0 = md0.lines().find(|l| l.contains("fleet split")).expect("fleet row");
        let cells0: Vec<&str> = row0.split('|').map(str::trim).collect();
        assert_eq!(cells0[7], "0", "{row0}");
    }

    /// An empty fleet (and shards that served nothing) must render an
    /// empty table — no phantom rows of zeros — and no gateway table.
    #[test]
    fn empty_fleet_renders_no_rows_and_no_gateway_table() {
        let empty = aggregate(Vec::<(ShardId, MetricsInner)>::new());
        assert_eq!(empty.total_requests(), 0);
        assert_eq!(empty.table(1.0).n_rows(), 0);
        assert!(empty.gateway_table().is_none());
        // a shard with zero traffic contributes no row either
        let idle = aggregate(vec![(ShardId(0), shard_with(&[]))]);
        assert_eq!(idle.table(1.0).n_rows(), 0);
    }

    /// `stage_window` is the per-stage analogue of the counter windows:
    /// each call returns only the attribution accumulated since the last
    /// one, and a reset (non-prefix) input saturates to zero.
    #[test]
    fn stage_window_deltas_cumulative_attribution() {
        let mut w = LoadWindow::new();
        let mut totals = StageNs::default();
        totals.ns[2] = 10_000; // queue
        totals.ns[4] = 4_000; // execute
        let first = w.stage_window(&totals);
        assert_eq!(first, totals, "first window is the whole history");
        assert_eq!(first.dominant(), Some("queue"));
        // the next interval adds mostly execute time: the window must see
        // only the increment and flip the dominant verdict
        totals.ns[4] += 20_000;
        totals.ns[2] += 1_000;
        let second = w.stage_window(&totals);
        assert_eq!(second.queue(), 1_000);
        assert_eq!(second.ns[4], 20_000);
        assert_eq!(second.dominant(), Some("execute"));
        // idle interval reads empty; a reset saturates instead of wrapping
        assert_eq!(w.stage_window(&totals).total(), 0);
        assert_eq!(w.stage_window(&StageNs::default()).total(), 0);
    }
}

//! Fleet-wide metric aggregation: merge per-shard `coordinator::Metrics`
//! snapshots into one fleet-level view.
//!
//! Percentiles are computed from the **merged histogram** — bucket counts
//! add across shards, so fleet p50/p95/p99 are quantiles of the combined
//! latency distribution. Averaging per-shard percentiles would understate
//! the tail whenever shards are imbalanced; the tests pin this down.

use crate::coordinator::metrics::{MetricsInner, RouteMetrics};
use crate::fleet::topology::ShardId;
use crate::util::tables::Table;

/// One shard's contribution to a fleet snapshot.
#[derive(Debug, Clone)]
pub struct ShardSnapshot {
    pub id: ShardId,
    pub metrics: MetricsInner,
}

/// Per-shard snapshots plus their merged fleet-level view.
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    pub shards: Vec<ShardSnapshot>,
    pub merged: MetricsInner,
}

/// Merge per-shard metric snapshots into a fleet snapshot.
pub fn aggregate(shards: impl IntoIterator<Item = (ShardId, MetricsInner)>) -> FleetSnapshot {
    let shards: Vec<ShardSnapshot> = shards
        .into_iter()
        .map(|(id, metrics)| ShardSnapshot { id, metrics })
        .collect();
    let mut merged = MetricsInner::default();
    for s in &shards {
        merged.merge(&s.metrics);
    }
    FleetSnapshot { shards, merged }
}

fn route_cells(name: &str, rm: &RouteMetrics, elapsed: f64) -> Option<Vec<String>> {
    if rm.requests == 0 {
        return None;
    }
    let q = |p: f64| rm.service.quantile_ns(p) / 1e6;
    let thr = if elapsed > 0.0 { rm.requests as f64 / elapsed } else { 0.0 };
    Some(vec![
        name.to_string(),
        rm.requests.to_string(),
        format!("{:.1}", rm.mean_batch()),
        format!("{:.2}", q(0.5)),
        format!("{:.2}", q(0.95)),
        format!("{:.2}", q(0.99)),
        format!("{thr:.0}"),
    ])
}

impl FleetSnapshot {
    pub fn total_requests(&self) -> u64 {
        self.merged.full.requests + self.merged.split.requests
    }

    pub fn total_dropped(&self) -> u64 {
        self.merged.dropped
    }

    /// Fleet table: one row per (shard, route) plus merged fleet rows.
    /// `elapsed` is the measurement window in seconds (for throughput).
    pub fn table(&self, elapsed: f64) -> Table {
        let mut t = Table::new(
            "Fleet serving metrics (percentiles from the merged histogram)",
            &["source", "requests", "mean batch", "p50 (ms)", "p95 (ms)", "p99 (ms)", "req/s"],
        );
        for s in &self.shards {
            for (route, rm) in
                [("server-only", &s.metrics.full), ("split", &s.metrics.split)]
            {
                if let Some(cells) = route_cells(&format!("{} {route}", s.id), rm, elapsed) {
                    t.row(&cells);
                }
            }
        }
        for (route, rm) in [("server-only", &self.merged.full), ("split", &self.merged.split)] {
            if let Some(cells) = route_cells(&format!("fleet {route}"), rm, elapsed) {
                t.row(&cells);
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Route;
    use crate::coordinator::Metrics;
    use std::time::Duration;

    fn shard_with(lat_ms: &[u64]) -> MetricsInner {
        let m = Metrics::new();
        for &ms in lat_ms {
            m.record_batch(
                Route::Split,
                1,
                0,
                Duration::from_micros(20),
                &[Duration::from_millis(1)],
                Duration::from_millis(1),
                &[Duration::from_millis(ms)],
            );
        }
        m.snapshot()
    }

    /// Fleet percentiles must equal the quantiles of one histogram holding
    /// every shard's samples — not any combination of per-shard percentiles.
    #[test]
    fn fleet_percentiles_come_from_the_merged_histogram() {
        // shard 0: 95 fast requests; shard 1: 5 slow ones
        let fast: Vec<u64> = vec![10; 95];
        let slow: Vec<u64> = vec![500; 5];
        let snap = aggregate(vec![
            (ShardId(0), shard_with(&fast)),
            (ShardId(1), shard_with(&slow)),
        ]);

        // reference: a single recorder that saw all 100 requests
        let mut all = fast.clone();
        all.extend_from_slice(&slow);
        let reference = shard_with(&all);

        assert_eq!(snap.merged.split.requests, 100);
        for q in [0.5, 0.9, 0.95, 0.99] {
            assert_eq!(
                snap.merged.split.service.quantile_ns(q),
                reference.split.service.quantile_ns(q),
                "fleet q{q} != single-histogram q{q}"
            );
        }

        // the failure mode this design avoids: averaging per-shard p99s
        // (10ms and 500ms → 255ms) hides that the true fleet p99 is ~500ms
        let p99_fleet = snap.merged.split.service.quantile_ns(0.99) / 1e6;
        let p99_avg = (snap.shards[0].metrics.split.service.quantile_ns(0.99)
            + snap.shards[1].metrics.split.service.quantile_ns(0.99))
            / 2.0
            / 1e6;
        assert!(p99_fleet > 400.0, "fleet p99 lost the tail: {p99_fleet}ms");
        assert!(p99_avg < 300.0, "sanity: averaging should understate ({p99_avg}ms)");
    }

    #[test]
    fn aggregate_sums_counters_across_shards() {
        let snap = aggregate(vec![
            (ShardId(0), shard_with(&[10, 10])),
            (ShardId(1), shard_with(&[10])),
            (ShardId(2), shard_with(&[])),
        ]);
        assert_eq!(snap.total_requests(), 3);
        assert_eq!(snap.shards.len(), 3);
        assert_eq!(snap.merged.split.batches, 3);
        assert_eq!(snap.merged.full.requests, 0);
    }

    #[test]
    fn table_renders_shard_and_fleet_rows() {
        let snap = aggregate(vec![
            (ShardId(0), shard_with(&[10; 4])),
            (ShardId(1), shard_with(&[20; 4])),
        ]);
        let t = snap.table(1.0);
        // 2 shard split rows + 1 fleet split row (no full traffic)
        assert_eq!(t.n_rows(), 3);
        let md = t.to_markdown();
        assert!(md.contains("fleet split"), "{md}");
        assert!(md.contains("shard-0 split"), "{md}");
    }
}

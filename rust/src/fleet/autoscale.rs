//! Histogram-driven fleet autoscaling: shard add/remove decisions from
//! the merged fleet metrics (DESIGN.md §10).
//!
//! The policy reads two fleet-wide signals — queue-wait p95 from the
//! merged latency histogram ([`super::aggregate`]) and the gateway's shed
//! rate — and answers one question per observation window: grow, shrink,
//! or hold. Three mechanisms keep it from flapping when a flash crowd
//! arrives or recedes:
//!
//!   * **hysteresis** — the scale-up threshold sits strictly above the
//!     scale-down threshold, so load oscillating inside the band produces
//!     no action at all;
//!   * **confirmation streaks** — pressure must persist for `confirm`
//!     consecutive samples before it becomes an action, so a single noisy
//!     histogram window cannot add a shard;
//!   * **cooldown** — after any action the policy holds for `cooldown`
//!     seconds, giving migration (and the forced-keyframe re-sync it
//!     triggers) time to settle before load is judged again.
//!
//! Like [`super::health::probe_transition`] and `net::limits::RateCap`,
//! the decision core is pure and time-agnostic: the caller supplies the
//! clock as `f64` seconds, so the threaded fleet feeds it wall time and
//! the deterministic simnet feeds it virtual time and gets byte-identical
//! decisions per seed.

/// One observation window's fleet-wide load signals.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LoadSample {
    /// queue-wait p95 in nanoseconds, from the merged fleet histogram
    /// (never from averaging per-shard percentiles)
    pub queue_p95_ns: u64,
    /// fraction of admission attempts shed by the gateway in the window,
    /// in `[0, 1]` (session sheds + quarantine drops over total attempts)
    pub shed_rate: f64,
    /// routable shards at sampling time — bounds the decision
    pub shards: usize,
}

/// What the fleet should do after one observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// load is inside the hysteresis band (or pressure is unconfirmed,
    /// or the cooldown is still running)
    Hold,
    /// add one shard: queue-wait p95 or shed rate persisted above the
    /// high watermark
    ScaleUp,
    /// drain and remove one shard: the fleet persisted below the low
    /// watermark with nothing shed
    ScaleDown,
}

/// Watermarks and damping for the autoscaler.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// never scale below this many shards
    pub min_shards: usize,
    /// never scale above this many shards
    pub max_shards: usize,
    /// queue-wait p95 above this sustains up-pressure
    pub queue_high_ns: u64,
    /// queue-wait p95 below this (with zero shed) sustains down-pressure;
    /// must sit strictly below `queue_high_ns` — the gap is the
    /// hysteresis band
    pub queue_low_ns: u64,
    /// shed rate above this sustains up-pressure regardless of queue wait
    /// (a fully shedding gateway can show an idle queue)
    pub shed_high: f64,
    /// shed rate at or below this counts as shed-free for down-pressure.
    /// Windowed rates are float quotients, so an exact-zero comparison
    /// would let one shed event in a million-request window latch
    /// scale-down off; must sit strictly below `shed_high`
    pub shed_low: f64,
    /// consecutive pressured samples required before acting
    pub confirm: u32,
    /// seconds after any action before the next may fire
    pub cooldown: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_shards: 1,
            max_shards: 16,
            queue_high_ns: 5_000_000, // 5 ms of queue wait at p95
            queue_low_ns: 500_000,    // 0.5 ms
            shed_high: 0.01,          // shedding >1% of admissions
            shed_low: 0.001,          // ≤0.1% reads as shed-free
            confirm: 3,
            cooldown: 30.0,
        }
    }
}

/// The damped decision state machine over [`LoadSample`]s.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    up_streak: u32,
    down_streak: u32,
    last_action_at: Option<f64>,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Self {
        assert!(cfg.queue_low_ns < cfg.queue_high_ns, "hysteresis band must be non-empty");
        assert!(cfg.shed_low < cfg.shed_high, "shed hysteresis band must be non-empty");
        assert!(cfg.min_shards >= 1, "a fleet needs at least one shard");
        assert!(cfg.min_shards <= cfg.max_shards, "min_shards exceeds max_shards");
        assert!(cfg.confirm >= 1, "confirm must require at least one sample");
        Autoscaler { cfg, up_streak: 0, down_streak: 0, last_action_at: None }
    }

    /// Current confirmation streaks `(up, down)` — for operator dashboards
    /// and scenario assertions.
    pub fn streaks(&self) -> (u32, u32) {
        (self.up_streak, self.down_streak)
    }

    /// Feed one observation window; `now` is seconds on any monotone
    /// clock. Streaks keep accumulating during the cooldown so pressure
    /// that persists across it acts immediately once the cooldown ends.
    pub fn observe(&mut self, now: f64, s: LoadSample) -> ScaleAction {
        let up_pressure = s.queue_p95_ns > self.cfg.queue_high_ns || s.shed_rate > self.cfg.shed_high;
        let down_pressure =
            s.queue_p95_ns < self.cfg.queue_low_ns && s.shed_rate <= self.cfg.shed_low;
        if up_pressure {
            self.up_streak = self.up_streak.saturating_add(1);
            self.down_streak = 0;
        } else if down_pressure {
            self.down_streak = self.down_streak.saturating_add(1);
            self.up_streak = 0;
        } else {
            // inside the hysteresis band: decay both directions
            self.up_streak = 0;
            self.down_streak = 0;
        }
        if let Some(t) = self.last_action_at {
            if now - t < self.cfg.cooldown {
                return ScaleAction::Hold;
            }
        }
        if self.up_streak >= self.cfg.confirm && s.shards < self.cfg.max_shards {
            self.up_streak = 0;
            self.down_streak = 0;
            self.last_action_at = Some(now);
            return ScaleAction::ScaleUp;
        }
        if self.down_streak >= self.cfg.confirm && s.shards > self.cfg.min_shards {
            self.up_streak = 0;
            self.down_streak = 0;
            self.last_action_at = Some(now);
            return ScaleAction::ScaleDown;
        }
        ScaleAction::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min_shards: 1,
            max_shards: 4,
            queue_high_ns: 1_000_000,
            queue_low_ns: 100_000,
            shed_high: 0.05,
            confirm: 3,
            cooldown: 10.0,
            ..AutoscaleConfig::default()
        }
    }

    fn hot(shards: usize) -> LoadSample {
        LoadSample { queue_p95_ns: 5_000_000, shed_rate: 0.0, shards }
    }

    fn idle(shards: usize) -> LoadSample {
        LoadSample { queue_p95_ns: 10_000, shed_rate: 0.0, shards }
    }

    fn banded(shards: usize) -> LoadSample {
        LoadSample { queue_p95_ns: 500_000, shed_rate: 0.0, shards }
    }

    #[test]
    fn sustained_queue_pressure_scales_up_after_confirmation() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(0.0, hot(2)), ScaleAction::Hold);
        assert_eq!(a.observe(1.0, hot(2)), ScaleAction::Hold);
        assert_eq!(a.observe(2.0, hot(2)), ScaleAction::ScaleUp, "third confirmed sample acts");
    }

    #[test]
    fn shed_rate_alone_scales_up_even_with_an_idle_queue() {
        // a gateway shedding everything shows no queue wait at all — the
        // shed signal must carry the decision by itself
        let mut a = Autoscaler::new(cfg());
        let shedding = LoadSample { queue_p95_ns: 0, shed_rate: 0.5, shards: 2 };
        assert_eq!(a.observe(0.0, shedding), ScaleAction::Hold);
        assert_eq!(a.observe(1.0, shedding), ScaleAction::Hold);
        assert_eq!(a.observe(2.0, shedding), ScaleAction::ScaleUp);
    }

    #[test]
    fn quiet_fleet_scales_down_after_confirmation() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(0.0, idle(3)), ScaleAction::Hold);
        assert_eq!(a.observe(1.0, idle(3)), ScaleAction::Hold);
        assert_eq!(a.observe(2.0, idle(3)), ScaleAction::ScaleDown);
    }

    #[test]
    fn shedding_vetoes_scale_down_even_below_the_low_watermark() {
        let mut a = Autoscaler::new(cfg());
        let deceptive = LoadSample { queue_p95_ns: 10_000, shed_rate: 0.2, shards: 3 };
        for i in 0..10 {
            assert_ne!(a.observe(i as f64, deceptive), ScaleAction::ScaleDown);
        }
    }

    #[test]
    fn hysteresis_band_never_acts_and_resets_streaks() {
        let mut a = Autoscaler::new(cfg());
        // two hot samples, then back in band: the streak must not survive
        a.observe(0.0, hot(2));
        a.observe(1.0, hot(2));
        assert_eq!(a.observe(2.0, banded(2)), ScaleAction::Hold);
        assert_eq!(a.streaks(), (0, 0));
        assert_eq!(a.observe(3.0, hot(2)), ScaleAction::Hold, "streak restarted from zero");
        // oscillation across the band edges without persistence: no action
        let mut b = Autoscaler::new(cfg());
        for i in 0..20 {
            let s = if i % 2 == 0 { hot(2) } else { idle(2) };
            assert_eq!(b.observe(i as f64, s), ScaleAction::Hold, "flapping load acted at {i}");
        }
    }

    #[test]
    fn cooldown_defers_the_next_action_but_keeps_the_streak() {
        let mut a = Autoscaler::new(cfg());
        a.observe(0.0, hot(2));
        a.observe(1.0, hot(2));
        assert_eq!(a.observe(2.0, hot(2)), ScaleAction::ScaleUp);
        // still hot, but inside the 10 s cooldown: hold
        for t in 3..12 {
            assert_eq!(a.observe(t as f64, hot(3)), ScaleAction::Hold, "acted inside cooldown");
        }
        // pressure persisted across the cooldown (streak ≥ confirm), so
        // the first sample past it acts immediately
        assert_eq!(a.observe(12.5, hot(3)), ScaleAction::ScaleUp);
    }

    #[test]
    fn shard_bounds_clamp_both_directions() {
        let mut a = Autoscaler::new(cfg());
        for t in 0..10 {
            assert_eq!(a.observe(t as f64, hot(4)), ScaleAction::Hold, "grew past max_shards");
        }
        let mut a = Autoscaler::new(cfg());
        for t in 0..10 {
            assert_eq!(a.observe(t as f64, idle(1)), ScaleAction::Hold, "shrank below min_shards");
        }
    }

    #[test]
    fn float_residue_below_shed_low_does_not_latch_scale_down_off() {
        // one shed in a large window leaves a tiny nonzero rate; the old
        // exact-zero comparison held scale-down off forever on it
        let mut a = Autoscaler::new(cfg());
        let residue = LoadSample { queue_p95_ns: 10_000, shed_rate: 1e-4, shards: 3 };
        assert_eq!(a.observe(0.0, residue), ScaleAction::Hold);
        assert_eq!(a.observe(1.0, residue), ScaleAction::Hold);
        assert_eq!(a.observe(2.0, residue), ScaleAction::ScaleDown);
    }

    #[test]
    #[should_panic(expected = "shed hysteresis")]
    fn shed_band_must_be_non_empty() {
        Autoscaler::new(AutoscaleConfig { shed_low: 0.01, shed_high: 0.01, ..cfg() });
    }

    /// Property (ISSUE 9 satellite): over seeded random load traces, the
    /// closed loop never emits a `ScaleUp` followed by a `ScaleDown` (or
    /// vice versa) within one cooldown — in fact no two actions land
    /// closer than the cooldown — and the simulated shard count stays
    /// inside `[min_shards, max_shards]` when every verdict is applied.
    #[test]
    fn anti_oscillation_property_over_random_load_traces() {
        use crate::util::proptest::{check, prop_assert};
        check(150, |g| {
            let cfg = AutoscaleConfig {
                min_shards: g.usize(1, 3),
                max_shards: g.usize(4, 8),
                queue_high_ns: 1_000_000,
                queue_low_ns: 100_000,
                shed_high: 0.05,
                shed_low: 0.001,
                confirm: g.usize(1, 4) as u32,
                cooldown: g.f64(1.0, 20.0),
            };
            let cooldown = cfg.cooldown;
            let (min_s, max_s) = (cfg.min_shards, cfg.max_shards);
            let mut a = Autoscaler::new(cfg);
            let mut shards = g.usize(min_s, max_s);
            let mut now = 0.0;
            let mut last: Option<(f64, ScaleAction)> = None;
            for _ in 0..200 {
                now += g.f64(0.1, 3.0);
                let s = LoadSample {
                    queue_p95_ns: g.u64(0, 3_000_000),
                    shed_rate: if g.bool() { 0.0 } else { g.f64(0.0, 0.2) },
                    shards,
                };
                let action = a.observe(now, s);
                match action {
                    ScaleAction::Hold => {}
                    ScaleAction::ScaleUp | ScaleAction::ScaleDown => {
                        if let Some((t, prev)) = last {
                            prop_assert(
                                now - t >= cooldown,
                                format!(
                                    "{action:?} at {now:.2} only {:.2}s after {prev:?} \
                                     (cooldown {cooldown:.2})",
                                    now - t
                                ),
                            )?;
                        }
                        last = Some((now, action));
                        if action == ScaleAction::ScaleUp {
                            shards += 1;
                        } else {
                            shards -= 1;
                        }
                    }
                }
                prop_assert(
                    (min_s..=max_s).contains(&shards),
                    format!("shard count {shards} escaped [{min_s}, {max_s}]"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn decisions_are_a_pure_function_of_the_sample_sequence() {
        // same samples, same clock -> same decisions (the determinism
        // contract the simnet relies on)
        let samples: Vec<LoadSample> =
            (0..30).map(|i| if i % 7 < 4 { hot(2) } else { idle(2) }).collect();
        let run = || {
            let mut a = Autoscaler::new(cfg());
            samples.iter().enumerate().map(|(i, s)| a.observe(i as f64, *s)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}

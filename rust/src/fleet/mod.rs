//! Sharded serving fleet: N coordinator shards behind a single front
//! gateway — the scale-out layer above `coordinator::serve`.
//!
//! * [`topology`] — consistent-hash ring + shard table (states, draining,
//!   connection counts). Sessions hash by their 32-bit id, so each client's
//!   server-side `SessionManager` stack stays shard-local.
//! * [`gateway`] — the front TCP endpoint speaking the existing
//!   `net::framing` protocol; pins each connection to its hashed shard and
//!   pumps frames both ways. Clients (and `coordinator::client::run_fleet`)
//!   point at the gateway instead of a single server — nothing else changes.
//! * [`health`] — `Hello` round-trip probes driving Up/Degraded/Down
//!   transitions in the shared topology.
//! * [`aggregate`] — merges per-shard `coordinator::Metrics` snapshots;
//!   fleet percentiles come from the combined histogram, never from
//!   averaging per-shard percentiles.
//! * [`autoscale`] — histogram-driven shard add/remove decisions (queue-wait
//!   p95 + gateway shed rate) with hysteresis and cooldown, pure over a
//!   caller-supplied clock so the simnet replays it deterministically.
//!
//! Shards are stock `coordinator::serve` instances (PJRT- or Sim-backed);
//! the gateway composes them rather than forking the server. The
//! [`launch_local`] helper boots an entire single-process fleet for tests,
//! benches, and the `serve_sharded` example.

pub mod aggregate;
pub mod autoscale;
pub mod gateway;
pub mod health;
pub mod topology;

pub use aggregate::{aggregate, FleetSnapshot, GatewayCounters, ShardSnapshot};
pub use autoscale::{Autoscaler, AutoscaleConfig, LoadSample, ScaleAction};
pub use gateway::{serve_gateway, GatewayConfig, GatewayHandle, GatewayStats};
pub use health::{probe_shard, probe_transition, HealthConfig, HealthMonitor, ProbeStats};
pub use topology::{HashRing, Shard, ShardId, ShardState, Topology};

use anyhow::{Context, Result};

use crate::coordinator::metrics::MetricsInner;
use crate::coordinator::{serve, ServerConfig, ServerHandle};

/// Configuration for a single-process local fleet.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// number of coordinator shards to launch
    pub shards: usize,
    /// gateway bind address
    pub gateway_addr: String,
    /// ring points per shard
    pub vnodes: usize,
    /// background probing for the gateway. On by default: a connect failure
    /// makes the gateway mark a shard Down, and without a monitor nothing
    /// ever brings it back (None = operator-driven states only)
    pub health: Option<HealthConfig>,
    /// template for every shard; `addr` is overridden with an ephemeral
    /// port and `shard_id` with the shard's index
    pub server: ServerConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            shards: 2,
            gateway_addr: "127.0.0.1:0".into(),
            vnodes: 64,
            health: Some(HealthConfig::default()),
            server: ServerConfig::default(),
        }
    }
}

/// A running fleet: the gateway plus its shard servers, all in-process.
pub struct LocalFleet {
    pub gateway: GatewayHandle,
    shards: Vec<(ShardId, ServerHandle)>,
}

/// Launch `cfg.shards` coordinator shards on ephemeral ports and a gateway
/// in front of them.
pub fn launch_local(cfg: FleetConfig) -> Result<LocalFleet> {
    anyhow::ensure!(cfg.shards > 0, "a fleet needs at least one shard");
    let mut shards = Vec::with_capacity(cfg.shards);
    for i in 0..cfg.shards {
        let id = ShardId(i as u16);
        let mut sc = cfg.server.clone();
        sc.addr = "127.0.0.1:0".into();
        sc.shard_id = Some(id.0);
        let handle = serve(sc).with_context(|| format!("launch {id}"))?;
        shards.push((id, handle));
    }
    let gateway = serve_gateway(GatewayConfig {
        addr: cfg.gateway_addr,
        shards: shards.iter().map(|(id, h)| (*id, h.addr)).collect(),
        vnodes: cfg.vnodes,
        health: cfg.health,
        ..GatewayConfig::default()
    })?;
    Ok(LocalFleet { gateway, shards })
}

impl LocalFleet {
    /// The address clients (e.g. `coordinator::client::run_fleet`) dial.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.gateway.addr
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard_ids(&self) -> Vec<ShardId> {
        self.shards.iter().map(|(id, _)| *id).collect()
    }

    /// One shard's raw metrics snapshot.
    pub fn shard_metrics(&self, id: ShardId) -> Option<MetricsInner> {
        self.shards
            .iter()
            .find(|(sid, _)| *sid == id)
            .map(|(_, h)| h.metrics.snapshot())
    }

    /// Merged fleet snapshot across all live shards, including the
    /// gateway's admission counters (shed/rate-capped sessions) so the
    /// autoscaler sees refusal pressure next to the latency histograms.
    pub fn snapshot(&self) -> FleetSnapshot {
        aggregate(self.shards.iter().map(|(id, h)| (*id, h.metrics.snapshot())))
            .with_gateway(self.gateway.stats().counters())
    }

    /// Push the gateway's current topology epoch down to every shard's
    /// admission gates, so stale or forged epoch-carrying hellos refuse
    /// fleet-wide (DESIGN.md §10).
    pub fn propagate_epoch(&self) {
        let epoch = self.gateway.topology_epoch();
        for (_, h) in &self.shards {
            h.set_topology_epoch(epoch);
        }
    }

    /// Hard-stop one shard (simulates a crash); the gateway discovers the
    /// loss via connect failures or health probes and routes around it.
    /// Returns false if the shard id is unknown.
    pub fn stop_shard(&mut self, id: ShardId) -> bool {
        if let Some(pos) = self.shards.iter().position(|(sid, _)| *sid == id) {
            let (_, handle) = self.shards.remove(pos);
            handle.shutdown();
            true
        } else {
            false
        }
    }

    pub fn shutdown(self) {
        self.gateway.shutdown();
        for (_, h) in self.shards {
            h.shutdown();
        }
    }
}

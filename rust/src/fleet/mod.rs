//! Sharded serving fleet: N coordinator shards behind a single front
//! gateway — the scale-out layer above `coordinator::serve`.
//!
//! * [`topology`] — consistent-hash ring + shard table (states, draining,
//!   connection counts). Sessions hash by their 32-bit id, so each client's
//!   server-side `SessionManager` stack stays shard-local.
//! * [`gateway`] — the front TCP endpoint speaking the existing
//!   `net::framing` protocol; pins each connection to its hashed shard and
//!   pumps frames both ways. Clients (and `coordinator::client::run_fleet`)
//!   point at the gateway instead of a single server — nothing else changes.
//! * [`health`] — `Hello` round-trip probes driving Up/Degraded/Down
//!   transitions in the shared topology.
//! * [`aggregate`] — merges per-shard `coordinator::Metrics` snapshots;
//!   fleet percentiles come from the combined histogram, never from
//!   averaging per-shard percentiles.
//! * [`autoscale`] — histogram-driven shard add/remove decisions (queue-wait
//!   p95 + gateway shed rate) with hysteresis and cooldown, pure over a
//!   caller-supplied clock so the simnet replays it deterministically.
//!
//! Shards are stock `coordinator::serve` instances (PJRT- or Sim-backed);
//! the gateway composes them rather than forking the server. The
//! [`launch_local`] helper boots an entire single-process fleet for tests,
//! benches, and the `serve_sharded` example.

pub mod aggregate;
pub mod autoscale;
pub mod gateway;
pub mod health;
pub mod topology;

pub use aggregate::{aggregate, FleetSnapshot, GatewayCounters, LoadWindow, ShardSnapshot};
pub use autoscale::{Autoscaler, AutoscaleConfig, LoadSample, ScaleAction};
pub use gateway::{serve_gateway, GatewayConfig, GatewayControl, GatewayHandle, GatewayStats};
pub use health::{probe_shard, probe_transition, HealthConfig, HealthMonitor, ProbeStats};
pub use topology::{HashRing, Shard, ShardId, ShardState, Topology};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use log::warn;

use crate::coordinator::metrics::MetricsInner;
use crate::coordinator::{serve, ServerConfig, ServerHandle};
use crate::util::signal::Signal;

/// Configuration for a single-process local fleet.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// number of coordinator shards to launch
    pub shards: usize,
    /// gateway bind address
    pub gateway_addr: String,
    /// ring points per shard
    pub vnodes: usize,
    /// background probing for the gateway. On by default: a connect failure
    /// makes the gateway mark a shard Down, and without a monitor nothing
    /// ever brings it back (None = operator-driven states only)
    pub health: Option<HealthConfig>,
    /// template for every shard; `addr` is overridden with an ephemeral
    /// port and `shard_id` with the shard's index
    pub server: ServerConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            shards: 2,
            gateway_addr: "127.0.0.1:0".into(),
            vnodes: 64,
            health: Some(HealthConfig::default()),
            server: ServerConfig::default(),
        }
    }
}

/// Wall-clock autoscaling for a [`LocalFleet`]: the same windowed sampler
/// and hysteresis policy the sim drives on virtual time (DESIGN.md §11),
/// run from a background thread against the live gateway.
#[derive(Debug, Clone)]
pub struct FleetAutoscaleConfig {
    /// watermarks, confirmation streaks, and cooldown; `cooldown` is in
    /// seconds of wall time on this path
    pub policy: AutoscaleConfig,
    /// sampling cadence of the background thread
    pub interval: Duration,
}

impl Default for FleetAutoscaleConfig {
    fn default() -> Self {
        FleetAutoscaleConfig {
            policy: AutoscaleConfig::default(),
            interval: Duration::from_millis(250),
        }
    }
}

/// One autoscaler verdict that actually changed the ring.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaleEvent {
    /// seconds since the sampler thread started
    pub at: f64,
    /// `ScaleUp` or `ScaleDown` — `Hold` verdicts are not recorded
    pub action: ScaleAction,
    /// the shard added to / removed from the ring
    pub shard: ShardId,
    /// the windowed load sample that confirmed the verdict
    pub sample: LoadSample,
}

/// The live shard process table, shared between the fleet handle and the
/// optional autoscale sampler thread.
type ShardTable = Arc<Mutex<Vec<(ShardId, ServerHandle)>>>;

/// The background sampler behind [`LocalFleet::start_autoscale`].
struct AutoscaleWorker {
    stop: Arc<AtomicBool>,
    signal: Arc<Signal>,
    events: Arc<Mutex<Vec<ScaleEvent>>>,
    thread: Option<thread::JoinHandle<()>>,
}

/// A running fleet: the gateway plus its shard servers, all in-process.
///
/// The shard table lives behind a mutex so the optional autoscaling
/// thread can park, revive, and launch shards while the owner keeps using
/// the fleet handle.
pub struct LocalFleet {
    pub gateway: GatewayHandle,
    shards: ShardTable,
    /// template the autoscaler launches fresh shards from
    server_template: ServerConfig,
    auto: Option<AutoscaleWorker>,
}

/// Launch `cfg.shards` coordinator shards on ephemeral ports and a gateway
/// in front of them.
pub fn launch_local(cfg: FleetConfig) -> Result<LocalFleet> {
    anyhow::ensure!(cfg.shards > 0, "a fleet needs at least one shard");
    let mut shards = Vec::with_capacity(cfg.shards);
    for i in 0..cfg.shards {
        let id = ShardId(i as u16);
        let mut sc = cfg.server.clone();
        sc.addr = "127.0.0.1:0".into();
        sc.shard_id = Some(id.0);
        let handle = serve(sc).with_context(|| format!("launch {id}"))?;
        shards.push((id, handle));
    }
    let gateway = serve_gateway(GatewayConfig {
        addr: cfg.gateway_addr,
        shards: shards.iter().map(|(id, h)| (*id, h.addr)).collect(),
        vnodes: cfg.vnodes,
        health: cfg.health,
        ..GatewayConfig::default()
    })?;
    Ok(LocalFleet {
        gateway,
        shards: Arc::new(Mutex::new(shards)),
        server_template: cfg.server,
        auto: None,
    })
}

impl LocalFleet {
    /// The address clients (e.g. `coordinator::client::run_fleet`) dial.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.gateway.addr
    }

    pub fn n_shards(&self) -> usize {
        self.shards.lock().unwrap().len()
    }

    pub fn shard_ids(&self) -> Vec<ShardId> {
        self.shards.lock().unwrap().iter().map(|(id, _)| *id).collect()
    }

    /// One shard's raw metrics snapshot.
    pub fn shard_metrics(&self, id: ShardId) -> Option<MetricsInner> {
        self.shards
            .lock()
            .unwrap()
            .iter()
            .find(|(sid, _)| *sid == id)
            .map(|(_, h)| h.metrics.snapshot())
    }

    /// Merged fleet snapshot across all live shards, including the
    /// gateway's admission counters (shed/rate-capped sessions) so the
    /// autoscaler sees refusal pressure next to the latency histograms.
    pub fn snapshot(&self) -> FleetSnapshot {
        let shards = self.shards.lock().unwrap();
        aggregate(shards.iter().map(|(id, h)| (*id, h.metrics.snapshot())))
            .with_gateway(self.gateway.stats().counters())
    }

    /// Push the gateway's current topology epoch down to every shard's
    /// admission gates, so stale or forged epoch-carrying hellos refuse
    /// fleet-wide (DESIGN.md §10).
    pub fn propagate_epoch(&self) {
        let epoch = self.gateway.topology_epoch();
        for (_, h) in self.shards.lock().unwrap().iter() {
            h.set_topology_epoch(epoch);
        }
    }

    /// Hard-stop one shard (simulates a crash); the gateway discovers the
    /// loss via connect failures or health probes and routes around it.
    /// Returns false if the shard id is unknown.
    pub fn stop_shard(&mut self, id: ShardId) -> bool {
        let handle = {
            let mut shards = self.shards.lock().unwrap();
            match shards.iter().position(|(sid, _)| *sid == id) {
                Some(pos) => shards.remove(pos).1,
                None => return false,
            }
        };
        handle.shutdown();
        true
    }

    /// Close the autoscaling loop over this fleet: a background thread
    /// samples the windowed load view every `cfg.interval` and applies the
    /// hysteresis policy's verdicts to the live ring. Scale-down parks the
    /// shard — it leaves the ring (pinned connections keep flowing) but
    /// the process stays up, so a later scale-up revives it without a
    /// relaunch; scale-up beyond the parked set boots fresh shards from
    /// the launch template. Panics if `cfg.policy` is inconsistent (same
    /// validation as [`Autoscaler::new`]); errors if already running.
    pub fn start_autoscale(&mut self, cfg: FleetAutoscaleConfig) -> Result<()> {
        anyhow::ensure!(self.auto.is_none(), "autoscale loop already running");
        anyhow::ensure!(!cfg.interval.is_zero(), "autoscale interval must be positive");
        let scaler = Autoscaler::new(cfg.policy.clone());
        let stop = Arc::new(AtomicBool::new(false));
        let signal = Arc::new(Signal::new());
        let events = Arc::new(Mutex::new(Vec::new()));
        let control = self.gateway.control();
        let shards = self.shards.clone();
        let template = self.server_template.clone();
        let (t_stop, t_signal, t_events) = (stop.clone(), signal.clone(), events.clone());
        let thread = thread::Builder::new()
            .name("fleet-autoscale".into())
            .spawn(move || {
                autoscale_loop(
                    cfg.interval,
                    scaler,
                    control,
                    shards,
                    template,
                    t_stop,
                    t_signal,
                    t_events,
                )
            })
            .context("spawn autoscale sampler")?;
        self.auto = Some(AutoscaleWorker { stop, signal, events, thread: Some(thread) });
        Ok(())
    }

    /// Every ring edit the autoscaler has made so far, oldest first.
    /// Empty when the loop was never started.
    pub fn scale_events(&self) -> Vec<ScaleEvent> {
        self.auto
            .as_ref()
            .map(|w| w.events.lock().unwrap().clone())
            .unwrap_or_default()
    }

    /// Block until `pred` holds over the scale-event log (re-checked after
    /// every ring edit) or `timeout` elapses; returns the final verdict.
    /// Immediately false when the loop was never started.
    pub fn wait_scale<F: Fn(&[ScaleEvent]) -> bool>(&self, timeout: Duration, pred: F) -> bool {
        match &self.auto {
            Some(w) => w.signal.wait_until(timeout, || pred(&w.events.lock().unwrap())),
            None => false,
        }
    }

    pub fn shutdown(mut self) {
        if let Some(mut w) = self.auto.take() {
            w.stop.store(true, Ordering::SeqCst);
            w.signal.notify();
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
        self.gateway.shutdown();
        let shards = std::mem::take(&mut *self.shards.lock().unwrap());
        for (_, h) in shards {
            h.shutdown();
        }
    }
}

/// Body of the `fleet-autoscale` sampler thread: interruptible sleep, one
/// windowed sample per tick, ring edits on confirmed verdicts.
#[allow(clippy::too_many_arguments)]
fn autoscale_loop(
    interval: Duration,
    mut scaler: Autoscaler,
    control: GatewayControl,
    shards: ShardTable,
    template: ServerConfig,
    stop: Arc<AtomicBool>,
    signal: Arc<Signal>,
    events: Arc<Mutex<Vec<ScaleEvent>>>,
) {
    let mut window = LoadWindow::new();
    let origin = Instant::now();
    loop {
        // wakes early only when `stop` flips (shutdown notifies the signal)
        if signal.wait_until(interval, || stop.load(Ordering::SeqCst)) {
            return;
        }
        let now = origin.elapsed().as_secs_f64();
        let snap = {
            let shards = shards.lock().unwrap();
            aggregate(shards.iter().map(|(id, h)| (*id, h.metrics.snapshot())))
        }
        .with_gateway(control.admission_counters());
        let sample = window.sample(&snap, control.n_routable());
        let action = scaler.observe(now, sample);
        let shard = match action {
            ScaleAction::Hold => continue,
            ScaleAction::ScaleUp => scale_up(&control, &shards, &template),
            ScaleAction::ScaleDown => scale_down(&control),
        };
        let Some(shard) = shard else { continue };
        // the ring edit bumped the topology epoch; push it to every
        // shard's admission gate so epoch-stamped hellos stay coherent
        let epoch = control.topology_epoch();
        for (_, h) in shards.lock().unwrap().iter() {
            h.set_topology_epoch(epoch);
        }
        events.lock().unwrap().push(ScaleEvent { at: now, action, shard, sample });
        signal.notify();
    }
}

/// Scale up by one shard: revive the lowest-id parked shard (in the
/// process table but out of the ring) if there is one, otherwise boot a
/// fresh shard from the launch template. Returns the shard that joined,
/// or None when launching failed (the verdict is dropped; pressure will
/// re-confirm).
fn scale_up(
    control: &GatewayControl,
    shards: &ShardTable,
    template: &ServerConfig,
) -> Option<ShardId> {
    let in_ring: Vec<ShardId> =
        control.shard_states().iter().map(|(id, _, _)| *id).collect();
    let mut shards = shards.lock().unwrap();
    if let Some((id, h)) = shards
        .iter()
        .filter(|(id, _)| !in_ring.contains(id))
        .min_by_key(|(id, _)| *id)
    {
        control.add_shard(*id, h.addr);
        return Some(*id);
    }
    let id = ShardId(shards.iter().map(|(sid, _)| sid.0 + 1).max().unwrap_or(0));
    let mut sc = template.clone();
    sc.addr = "127.0.0.1:0".into();
    sc.shard_id = Some(id.0);
    match serve(sc) {
        Ok(h) => {
            control.add_shard(id, h.addr);
            shards.push((id, h));
            Some(id)
        }
        Err(e) => {
            warn!("autoscale: failed to launch {id}: {e:#}");
            None
        }
    }
}

/// Scale down by one shard: pull the highest-id routable shard out of the
/// ring. Pinned connections keep flowing and the process stays up
/// (parked) so a later scale-up revives it without a relaunch.
fn scale_down(control: &GatewayControl) -> Option<ShardId> {
    let id = control
        .shard_states()
        .iter()
        .filter(|(_, state, _)| state.routable())
        .map(|(id, _, _)| *id)
        .max()?;
    control.remove_shard(id);
    Some(id)
}

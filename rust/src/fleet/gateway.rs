//! The fleet front gateway: one TCP endpoint speaking the existing
//! `net::framing` wire protocol, fanning each connection out to the
//! coordinator shard its session hashes to.
//!
//! Thread layout (mirrors the coordinator's):
//!   * accept thread — owns the listener, spawns one connection thread per
//!     client;
//!   * connection threads — read the first frame to learn the session id,
//!     consult the shared [`Topology`] for a consistent-hash placement, pin
//!     an upstream connection to that shard, then pump frames client→shard
//!     inline while a paired pump thread copies shard→client;
//!   * (optional) health-monitor thread — probes shards and edits the
//!     topology; the next placement simply routes around `Down` shards.
//!
//! The gateway acks a client's opening `Hello` itself, stamping the
//! assigned shard id into the `shard` field; shard-side hello acks are
//! filtered out of the return path so a client sees exactly one ack.

use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};
use log::{debug, warn};

use crate::net::framing::{
    ErrorMsg, Hello, Msg, CAP_TRACE, ERR_OVERLOADED, MSG_ERROR, MSG_HELLO, MSG_REQUEST_FEAT,
    MSG_REQUEST_FEAT_V2, MSG_REQUEST_RAW, MSG_RESPONSE, MSG_RESPONSE_V2,
};
use crate::net::limits::{FrameLimits, LimitsConfig, RateCap};
use crate::net::tcp::{
    read_msg, read_msg_limited, read_raw_frame, read_raw_frame_limited, write_msg,
    write_raw_frame,
};
use crate::trace;
use crate::util::signal::Signal;

use super::health::{HealthConfig, HealthMonitor};
use super::topology::{ShardId, ShardState, Topology};

#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// bind address; use port 0 for an ephemeral port
    pub addr: String,
    /// shard endpoints, all already listening
    pub shards: Vec<(ShardId, SocketAddr)>,
    /// ring points per shard
    pub vnodes: usize,
    /// deadline for pinning an upstream connection
    pub connect_timeout: Duration,
    /// background probing; None leaves state transitions to the operator.
    /// Note that a refused pin marks a shard Down, and only a health
    /// monitor (or an explicit `set_shard_state`) can bring it back up —
    /// prefer `Some` unless states are managed externally
    pub health: Option<HealthConfig>,
    /// hostile-input resource budgets (DESIGN.md §9): per-type frame-size
    /// caps applied to every client→shard pump read
    pub limits: LimitsConfig,
    /// bounded accept queue: connections past this many live sessions are
    /// shed with an explicit [`ERR_OVERLOADED`] frame instead of queueing
    /// behind the batcher (clients back off with jittered retries)
    pub max_conns: usize,
    /// per-session request rate cap in requests/s (0.0 disables); excess
    /// requests are answered with [`ERR_OVERLOADED`], the session survives
    pub rate_hz: f64,
    /// token-bucket burst allowance for the rate cap
    pub rate_burst: f64,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            addr: "127.0.0.1:0".into(),
            shards: Vec::new(),
            vnodes: 64,
            connect_timeout: Duration::from_secs(1),
            health: None,
            limits: LimitsConfig::default(),
            max_conns: 1024,
            rate_hz: 0.0,
            rate_burst: 32.0,
        }
    }
}

/// Admission-control state shared by every gateway connection
/// (DESIGN.md §9): the config knobs plus the live-connection gauge the
/// bounded accept queue is enforced against.
struct Admission {
    limits: LimitsConfig,
    max_conns: usize,
    rate_hz: f64,
    rate_burst: f64,
    live: AtomicUsize,
}

/// Releases the live-connection gauge however the connection ends.
struct LiveGuard<'a>(&'a AtomicUsize);

impl Drop for LiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Per-frame counters, lock-free so the two pump directions of every
/// connection never serialize on a mutex (the shard set is fixed at
/// gateway start, so the per-shard map needs no locking either).
struct Counters {
    forwarded_requests: AtomicU64,
    forwarded_responses: AtomicU64,
    /// requests refused by the per-session rate cap (frame-rate, so it
    /// lives with the lock-free counters, not the mutexed stats)
    rate_limited: AtomicU64,
    per_shard_requests: HashMap<ShardId, AtomicU64>,
}

impl Counters {
    fn count_request(&self, shard: ShardId) {
        self.forwarded_requests.fetch_add(1, Ordering::SeqCst);
        if let Some(c) = self.per_shard_requests.get(&shard) {
            c.fetch_add(1, Ordering::SeqCst);
        }
    }
}

/// Gateway-side statistics snapshot. Connection-rate fields live behind a
/// mutex (touched once per connection); frame-rate fields are read from
/// the internal lock-free counters.
#[derive(Debug, Clone, Default)]
pub struct GatewayStats {
    /// client connections accepted
    pub connections: u64,
    /// connections rejected for lack of a routable shard
    pub rejected: u64,
    /// request frames forwarded client→shard
    pub forwarded_requests: u64,
    /// response frames forwarded shard→client
    pub forwarded_responses: u64,
    /// session -> pinned shard, as observed across all connections
    pub assignments: HashMap<u32, ShardId>,
    /// request frames per shard
    pub per_shard_requests: HashMap<ShardId, u64>,
    /// sessions whose placement changed between connections — stays 0 while
    /// the routable set is stable (the session-affinity invariant)
    pub reassigned: u64,
    /// connections shed by the bounded accept queue (answered with an
    /// explicit [`ERR_OVERLOADED`] frame, DESIGN.md §9)
    pub shed_connections: u64,
    /// requests refused by the per-session rate cap (the session survives)
    pub rate_limited: u64,
}

impl GatewayStats {
    /// Fold this gateway's admission counters into the fleet-snapshot form
    /// (`fleet::aggregate`), so shed/quarantine pressure is visible next to
    /// the merged latency histograms. The threaded gateway quarantines
    /// nothing itself — hostile-budget quarantine lives in the shard
    /// readers — so those fields stay zero here; the simnet gateway fills
    /// them from its own outcome counters.
    pub fn counters(&self) -> super::aggregate::GatewayCounters {
        super::aggregate::GatewayCounters {
            shed_sessions: self.shed_connections,
            rate_limited: self.rate_limited,
            quarantined_sessions: 0,
            quarantine_drops: 0,
        }
    }
}

pub struct GatewayHandle {
    pub addr: SocketAddr,
    topology: Arc<Mutex<Topology>>,
    stats: Arc<Mutex<GatewayStats>>,
    counters: Arc<Counters>,
    health: Option<HealthMonitor>,
    shutdown: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    /// notified after every observable state change (stats, topology,
    /// probe verdicts) — the event-driven replacement for sleep-polling
    signal: Arc<Signal>,
}

impl GatewayHandle {
    /// The topology's current epoch (bumped by every add/remove/state
    /// change — probe verdicts included). Stamped into hello acks so
    /// clients can detect stale re-routes (DESIGN.md §10).
    pub fn topology_epoch(&self) -> u64 {
        self.topology.lock().unwrap().epoch()
    }

    pub fn stats(&self) -> GatewayStats {
        let mut s = self.stats.lock().unwrap().clone();
        s.forwarded_requests = self.counters.forwarded_requests.load(Ordering::SeqCst);
        s.forwarded_responses = self.counters.forwarded_responses.load(Ordering::SeqCst);
        s.rate_limited = self.counters.rate_limited.load(Ordering::SeqCst);
        s.per_shard_requests = self
            .counters
            .per_shard_requests
            .iter()
            .map(|(id, c)| (*id, c.load(Ordering::SeqCst)))
            .collect();
        s
    }

    /// Begin draining a shard: pinned connections keep flowing, new sessions
    /// hash elsewhere.
    pub fn drain(&self, id: ShardId) {
        self.topology.lock().unwrap().drain(id);
        self.signal.notify();
    }

    /// True once a draining shard has no pinned connections left.
    pub fn drained(&self, id: ShardId) -> bool {
        self.topology.lock().unwrap().drained(id)
    }

    pub fn set_shard_state(&self, id: ShardId, state: ShardState) {
        self.topology.lock().unwrap().set_state(id, state);
        self.signal.notify();
    }

    /// Block until `pred` holds over the stats snapshot (re-checked on
    /// every connection/topology event) or `timeout` elapses; returns the
    /// final verdict.
    pub fn wait_stats<F: Fn(&GatewayStats) -> bool>(&self, timeout: Duration, pred: F) -> bool {
        self.signal.wait_until(timeout, || pred(&self.stats()))
    }

    /// Event-driven drain completion: true once the shard is Draining with
    /// zero pinned connections.
    pub fn wait_drained(&self, id: ShardId, timeout: Duration) -> bool {
        self.signal
            .wait_until(timeout, || self.topology.lock().unwrap().drained(id))
    }

    /// Block until a shard reaches `state` (via probes, refused pins, or
    /// operator edits) or `timeout` elapses.
    pub fn wait_shard_state(&self, id: ShardId, state: ShardState, timeout: Duration) -> bool {
        self.signal
            .wait_until(timeout, || self.topology.lock().unwrap().state(id) == Some(state))
    }

    /// (id, state, live connections) per shard.
    pub fn shard_states(&self) -> Vec<(ShardId, ShardState, usize)> {
        let top = self.topology.lock().unwrap();
        top.shards().map(|s| (s.id, s.state, s.connections)).collect()
    }

    /// Probe stats from the health monitor, if one is running.
    pub fn health_stats(&self) -> Option<HashMap<ShardId, super::health::ProbeStats>> {
        self.health.as_ref().map(|h| h.stats())
    }

    /// Add a shard to the live ring (scale-up): new sessions start hashing
    /// to it immediately and the topology epoch bumps. The shard must
    /// already be listening on `addr`. Runtime joiners are not in the
    /// fixed per-shard request map, so `per_shard_requests` simply has no
    /// entry for them — the aggregate counters still see every frame.
    pub fn add_shard(&self, id: ShardId, addr: SocketAddr) {
        self.topology.lock().unwrap().add_shard(id, addr);
        self.signal.notify();
    }

    /// Remove a shard from the ring (planned scale-down): the epoch bumps
    /// and no new session routes to it, while connections already pinned
    /// keep flowing until they close — keep the shard process up until
    /// `drained` (or connection counts) say it is quiescent.
    pub fn remove_shard(&self, id: ShardId) {
        self.topology.lock().unwrap().remove_shard(id);
        self.signal.notify();
    }

    /// Shards currently routable (`Up` and not draining) — the fleet size
    /// an autoscaler verdict is judged against.
    pub fn n_routable(&self) -> usize {
        self.topology.lock().unwrap().n_routable()
    }

    /// A clonable, thread-safe view of the gateway's shared state for
    /// background samplers (the autoscaling loop): admission counters,
    /// topology edits, and the event signal — everything a sampler needs
    /// without owning the handle (which the fleet keeps for shutdown).
    pub fn control(&self) -> GatewayControl {
        GatewayControl {
            topology: self.topology.clone(),
            stats: self.stats.clone(),
            counters: self.counters.clone(),
            signal: self.signal.clone(),
        }
    }

    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.health.take() {
            h.stop();
        }
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Detached view of a running gateway's shared state — see
/// [`GatewayHandle::control`]. Clonable and `Send`, so the autoscaling
/// sampler thread can watch counters and edit the ring while the handle
/// itself stays with the fleet for shutdown.
#[derive(Clone)]
pub struct GatewayControl {
    topology: Arc<Mutex<Topology>>,
    stats: Arc<Mutex<GatewayStats>>,
    counters: Arc<Counters>,
    signal: Arc<Signal>,
}

impl GatewayControl {
    /// Cumulative admission counters in the fleet-snapshot form. Like
    /// [`GatewayStats::counters`], quarantine fields stay zero on the
    /// threaded path (hostile-budget quarantine lives in the shard
    /// readers).
    pub fn admission_counters(&self) -> super::aggregate::GatewayCounters {
        let shed = self.stats.lock().unwrap().shed_connections;
        super::aggregate::GatewayCounters {
            shed_sessions: shed,
            rate_limited: self.counters.rate_limited.load(Ordering::SeqCst),
            quarantined_sessions: 0,
            quarantine_drops: 0,
        }
    }

    /// Cumulative request frames forwarded client→shard.
    pub fn total_requests(&self) -> u64 {
        self.counters.forwarded_requests.load(Ordering::SeqCst)
    }

    pub fn n_routable(&self) -> usize {
        self.topology.lock().unwrap().n_routable()
    }

    pub fn topology_epoch(&self) -> u64 {
        self.topology.lock().unwrap().epoch()
    }

    /// `(id, state, addr)` per shard currently in the table, in id order.
    pub fn shard_states(&self) -> Vec<(ShardId, ShardState, SocketAddr)> {
        let top = self.topology.lock().unwrap();
        top.shards().map(|s| (s.id, s.state, s.addr)).collect()
    }

    /// See [`GatewayHandle::add_shard`].
    pub fn add_shard(&self, id: ShardId, addr: SocketAddr) {
        self.topology.lock().unwrap().add_shard(id, addr);
        self.signal.notify();
    }

    /// See [`GatewayHandle::remove_shard`].
    pub fn remove_shard(&self, id: ShardId) {
        self.topology.lock().unwrap().remove_shard(id);
        self.signal.notify();
    }
}

/// Start the gateway in front of an already-listening shard set.
pub fn serve_gateway(cfg: GatewayConfig) -> Result<GatewayHandle> {
    anyhow::ensure!(!cfg.shards.is_empty(), "gateway needs at least one shard");
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
    let addr = listener.local_addr()?;

    let mut topology = Topology::new(cfg.vnodes);
    for (id, saddr) in &cfg.shards {
        topology.add_shard(*id, *saddr);
    }
    let topology = Arc::new(Mutex::new(topology));
    let stats = Arc::new(Mutex::new(GatewayStats::default()));
    let counters = Arc::new(Counters {
        forwarded_requests: AtomicU64::new(0),
        forwarded_responses: AtomicU64::new(0),
        rate_limited: AtomicU64::new(0),
        per_shard_requests: cfg
            .shards
            .iter()
            .map(|(id, _)| (*id, AtomicU64::new(0)))
            .collect(),
    });
    let shutdown = Arc::new(AtomicBool::new(false));
    let signal = Arc::new(Signal::new());
    let health = cfg
        .health
        .clone()
        .map(|h| HealthMonitor::start_with(topology.clone(), h, signal.clone()));

    let acc_shutdown = shutdown.clone();
    let acc_topology = topology.clone();
    let acc_stats = stats.clone();
    let acc_counters = counters.clone();
    let acc_signal = signal.clone();
    let connect_timeout = cfg.connect_timeout;
    let admission = Arc::new(Admission {
        limits: cfg.limits.clone(),
        max_conns: cfg.max_conns,
        rate_hz: cfg.rate_hz,
        rate_burst: cfg.rate_burst,
        live: AtomicUsize::new(0),
    });
    let acceptor = std::thread::Builder::new()
        .name("gw-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if acc_shutdown.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let topology = acc_topology.clone();
                        let stats = acc_stats.clone();
                        let counters = acc_counters.clone();
                        let shutdown = acc_shutdown.clone();
                        let signal = acc_signal.clone();
                        let admission = admission.clone();
                        std::thread::Builder::new()
                            .name("gw-conn".into())
                            .spawn(move || {
                                let r = gw_conn(
                                    s,
                                    topology,
                                    stats,
                                    counters,
                                    shutdown,
                                    connect_timeout,
                                    &admission,
                                    &signal,
                                );
                                if let Err(e) = r {
                                    debug!("gateway connection ended: {e:#}");
                                }
                                // the connection's final state edits are
                                // visible: wake any waiters
                                signal.notify();
                            })
                            .ok();
                    }
                    Err(e) => {
                        warn!("gateway accept error: {e}");
                        break;
                    }
                }
            }
        })
        .context("spawn gateway acceptor")?;

    Ok(GatewayHandle {
        addr,
        topology,
        stats,
        counters,
        health,
        shutdown,
        threads: vec![acceptor],
        signal,
    })
}

/// Serve one client connection end to end.
#[allow(clippy::too_many_arguments)]
fn gw_conn(
    mut client: TcpStream,
    topology: Arc<Mutex<Topology>>,
    stats: Arc<Mutex<GatewayStats>>,
    counters: Arc<Counters>,
    shutdown: Arc<AtomicBool>,
    connect_timeout: Duration,
    admission: &Admission,
    signal: &Signal,
) -> Result<()> {
    client.set_nodelay(true).ok();
    let admitted = admission.live.fetch_add(1, Ordering::SeqCst) < admission.max_conns;
    let _live = LiveGuard(&admission.live);

    // the first frame names the session this connection belongs to; it is
    // read under the pre-Hello caps — an unnegotiated peer never buys a
    // large allocation (DESIGN.md §9)
    let pre_hello = FrameLimits::pre_hello(&admission.limits);
    let mut first_buf = Vec::new();
    let first = match read_msg_limited(&mut client, &mut first_buf, &pre_hello)? {
        Some(Ok(m)) => m,
        Some(Err(e)) => bail!("client opened with an undecodable frame: {e:#}"),
        None => return Ok(()), // connected and left (e.g. the shutdown poke)
    };
    let session = match &first {
        Msg::Hello(h) => h.client,
        Msg::Request(r) => r.client,
        Msg::Response(_) | Msg::ResponseV2(_) | Msg::ResponseLearn(_) | Msg::Error(_)
        | Msg::Policy(_) => bail!("client opened with a server-side frame"),
    };

    // bounded accept queue: past capacity, shed with an explicit overload
    // frame instead of stalling the batcher — the client backs off with a
    // jittered retry and the fleet degrades gracefully
    if !admitted {
        let err = Msg::Error(ErrorMsg {
            client: session,
            code: ERR_OVERLOADED,
            detail: "gateway at connection capacity; retry with backoff".into(),
        });
        let _ = write_msg(&mut client, &err);
        stats.lock().unwrap().shed_connections += 1;
        signal.notify();
        debug!("shed session {session}: gateway at connection capacity");
        return Ok(());
    }

    // fix the per-type frame caps for the pump: a Hello pins them to the
    // negotiated route (widened by the fixed trace-trailer allowance on
    // trace-negotiated sessions); a bare request keeps the pre-Hello union
    let pump_limits = match &first {
        Msg::Hello(h) => {
            let mut l = FrameLimits::negotiated(h.split, &admission.limits);
            if h.caps & CAP_TRACE != 0 {
                l.allow_trace();
            }
            l
        }
        _ => pre_hello,
    };

    // consistent-hash placement, re-routing around shards that refuse the
    // pin (each refusal marks the shard Down for everyone)
    let mut attempts = 0usize;
    let (shard_id, upstream, epoch) = loop {
        let pick = {
            let top = topology.lock().unwrap();
            top.route(session).map(|s| (s.id, s.addr, top.epoch()))
        };
        let Some((id, saddr, epoch)) = pick else {
            stats.lock().unwrap().rejected += 1;
            signal.notify();
            bail!("no routable shard for session {session}");
        };
        match TcpStream::connect_timeout(&saddr, connect_timeout) {
            Ok(s) => break (id, s, epoch),
            Err(e) => {
                warn!("gateway: {id} refused pin ({e}); marking down and re-routing");
                topology.lock().unwrap().set_state(id, ShardState::Down);
                signal.notify();
                attempts += 1;
                if attempts > 16 {
                    stats.lock().unwrap().rejected += 1;
                    signal.notify();
                    bail!("session {session}: no shard accepted the pin");
                }
            }
        }
    };
    upstream.set_nodelay(true).ok();
    topology.lock().unwrap().conn_opened(shard_id);
    {
        let mut st = stats.lock().unwrap();
        st.connections += 1;
        match st.assignments.insert(session, shard_id) {
            Some(prev) if prev != shard_id => st.reassigned += 1,
            _ => {}
        }
    }
    signal.notify();

    let result = pump_session(
        &mut client,
        upstream,
        &first,
        session,
        shard_id,
        epoch,
        &counters,
        &shutdown,
        &pump_limits,
        admission,
    );
    topology.lock().unwrap().conn_closed(shard_id);
    signal.notify();
    result
}

#[allow(clippy::too_many_arguments)]
fn pump_session(
    client: &mut TcpStream,
    mut upstream: TcpStream,
    first: &Msg,
    session: u32,
    shard_id: ShardId,
    epoch: u64,
    counters: &Arc<Counters>,
    shutdown: &Arc<AtomicBool>,
    limits: &FrameLimits,
    admission: &Admission,
) -> Result<()> {
    // tracing rides the session's negotiated capability: the forward pump
    // stamps its hop only for sessions that asked for it
    let traced = matches!(first, Msg::Hello(h) if h.caps & CAP_TRACE != 0);
    // the gateway speaks for the fleet: ack the opening hello with the
    // assigned shard before any traffic flows. Because the shard's own
    // hello ack is filtered off the return path, the gateway must apply
    // the same codec-negotiation rule the shard reader does (echo known
    // ids, decline unknown ones to flat) — otherwise a shard's decline
    // could never reach a fleet client
    if let Msg::Hello(h) = first {
        let codec = if crate::codec::CodecId::from_wire(h.codec).is_some() { h.codec } else { 0 };
        write_msg(
            client,
            &Msg::Hello(Hello {
                client: h.client,
                split: h.split,
                codec,
                // the threaded gateway does not negotiate experience
                // streaming (learning clients connect shard-direct;
                // the simnet gateway models versioned fan-out), but it
                // passes the tracing grant through: the hello is forwarded
                // verbatim, so trace-enabled shards make the same verdict
                // (a fleet is deployed with tracing on or off as a whole)
                caps: h.caps & CAP_TRACE,
                shard: Some(shard_id.0),
                // the topology epoch this placement was computed under:
                // the client echoes it on reconnect, and shards refuse
                // hellos whose epoch went stale mid-migration
                epoch: Some(epoch),
            }),
        )?;
    }
    write_msg(&mut upstream, first)?;
    if matches!(first, Msg::Request(_)) {
        counters.count_request(shard_id);
    }

    // Both pumps forward frames **verbatim**: one pooled buffer per
    // direction, a one-byte type peek for counters/filtering, no
    // decode/re-encode round trip — per-frame cost is a read, a tag
    // branch, and a write (DistrEdge's partitioned-serving lesson: data
    // movement, not compute, dominates the proxy path).

    // the client writer is shared between the return pump and the forward
    // pump's overload replies, so shed frames never interleave mid-frame
    // with a response copy
    let client_write = Arc::new(Mutex::new(client.try_clone().context("clone client stream")?));

    // shard -> client pump (hello acks already handled above)
    let mut up_read = upstream.try_clone().context("clone upstream")?;
    let back_write = client_write.clone();
    let pump_counters = counters.clone();
    let back = std::thread::Builder::new()
        .name("gw-pump".into())
        .spawn(move || {
            let mut frame = Vec::new();
            loop {
                match read_raw_frame(&mut up_read, &mut frame) {
                    Ok(true) => {
                        match frame[0] {
                            // shard-side hello acks stay internal to the fleet
                            MSG_HELLO => continue,
                            MSG_RESPONSE | MSG_RESPONSE_V2 => {
                                pump_counters
                                    .forwarded_responses
                                    .fetch_add(1, Ordering::SeqCst);
                            }
                            // the shard's explicit rejection frames must
                            // reach fleet clients (capability refusals,
                            // overload sheds)
                            MSG_ERROR => {}
                            MSG_REQUEST_RAW | MSG_REQUEST_FEAT | MSG_REQUEST_FEAT_V2 => {}
                            // a corrupt/version-skewed shard must surface at
                            // the gateway boundary, not be relayed onward
                            other => {
                                warn!("shard {shard_id} sent unknown frame type {other}");
                                break;
                            }
                        }
                        let mut w = back_write.lock().unwrap();
                        if write_raw_frame(&mut *w, &frame).is_err() {
                            break;
                        }
                    }
                    Ok(false) | Err(_) => break,
                }
            }
        })
        .context("spawn return pump")?;

    // client -> shard pump, inline. Reads run under the session's per-type
    // frame caps: an oversize claim or unknown type is a transport
    // violation (the body is unread, framing is desynced) and drops the
    // connection — the gateway never buys a hostile allocation
    let mut rate = (admission.rate_hz > 0.0)
        .then(|| RateCap::new(admission.rate_hz, admission.rate_burst));
    let t0 = Instant::now();
    let forward = (|| -> Result<()> {
        let mut frame = Vec::new();
        loop {
            if shutdown.load(Ordering::SeqCst) {
                break;
            }
            if !read_raw_frame_limited(client, &mut frame, limits)? {
                break; // client done
            }
            match frame[0] {
                MSG_REQUEST_RAW | MSG_REQUEST_FEAT | MSG_REQUEST_FEAT_V2 => {
                    // per-session rate cap: excess requests are shed with
                    // an explicit overload frame, never forwarded — the
                    // batcher's queue stays owned by compliant traffic,
                    // and the session itself survives
                    if let Some(rc) = rate.as_mut() {
                        if !rc.allow(t0.elapsed().as_secs_f64()) {
                            counters.rate_limited.fetch_add(1, Ordering::SeqCst);
                            let err = Msg::Error(ErrorMsg {
                                client: session,
                                code: ERR_OVERLOADED,
                                detail: "per-session rate cap exceeded; retry with backoff"
                                    .into(),
                            });
                            let mut w = client_write.lock().unwrap();
                            if write_msg(&mut *w, &err).is_err() {
                                break;
                            }
                            continue;
                        }
                    }
                    // stamp the forward hop into the trace trailer in
                    // place: a byte patch at a fixed tail offset, never a
                    // decode — the pump stays a verbatim copy otherwise
                    if traced {
                        trace::stamp_body_tail(
                            &mut frame,
                            trace::STAGE_GW_FORWARD,
                            trace::ns_since_epoch(Instant::now()),
                        );
                    }
                    counters.count_request(shard_id)
                }
                MSG_HELLO | MSG_RESPONSE | MSG_RESPONSE_V2 => {}
                other => anyhow::bail!("client sent unknown frame type {other}"),
            }
            write_raw_frame(&mut upstream, &frame)
                .with_context(|| format!("forward to {shard_id}"))?;
        }
        Ok(())
    })();

    // tear the upstream down so the return pump unblocks, then reap it
    let _ = upstream.shutdown(Shutdown::Both);
    let _ = back.join();
    debug!("session {session} on {shard_id} closed");
    forward
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{serve, Backend, ServerConfig, ServerHandle, SimSpec};
    use crate::net::framing::{Payload, Request};

    fn sim_shard(id: u16) -> ServerHandle {
        serve(ServerConfig {
            shard_id: Some(id),
            backend: Backend::Sim(SimSpec {
                fixed: Duration::from_micros(200),
                per_item: Duration::from_micros(50),
                action_dim: 1,
                encode: false,
            }),
            ..ServerConfig::default()
        })
        .expect("sim shard")
    }

    fn gateway_over(shards: &[&ServerHandle]) -> GatewayHandle {
        serve_gateway(GatewayConfig {
            shards: shards
                .iter()
                .enumerate()
                .map(|(i, s)| (ShardId(i as u16), s.addr))
                .collect(),
            ..GatewayConfig::default()
        })
        .expect("gateway")
    }

    /// Raw-protocol round trip through the gateway: hello ack carries the
    /// shard id, request comes back answered by the shard.
    #[test]
    fn hello_ack_names_the_assigned_shard_and_requests_flow() {
        let s0 = sim_shard(0);
        let s1 = sim_shard(1);
        let gw = gateway_over(&[&s0, &s1]);

        let mut conn = TcpStream::connect(gw.addr).unwrap();
        write_msg(
            &mut conn,
            &Msg::Hello(Hello { client: 5, split: false, codec: 0, caps: 0, shard: None, epoch: None }),
        )
            .unwrap();
        let ack = read_msg(&mut conn).unwrap().unwrap();
        let assigned = match ack {
            Msg::Hello(h) => {
                // two add_shard calls built this topology: the ack stamps
                // the epoch the placement was computed under
                assert_eq!(h.epoch, Some(2), "ack must carry the topology epoch");
                h.shard.expect("gateway must stamp a shard")
            }
            other => panic!("expected hello ack, got {other:?}"),
        };
        assert!(assigned < 2);
        assert_eq!(gw.topology_epoch(), 2);

        let x = 8u16;
        write_msg(
            &mut conn,
            &Msg::Request(Request {
                client: 5,
                id: 99,
                payload: Payload::RawRgba { x, data: vec![1; 4 * 8 * 8] },
            }),
        )
        .unwrap();
        let resp = loop {
            match read_msg(&mut conn).unwrap().unwrap() {
                Msg::Response(r) => break r,
                _ => continue,
            }
        };
        assert_eq!(resp.id, 99);
        assert_eq!(resp.action.len(), 1);

        let st = gw.stats();
        assert_eq!(st.assignments[&5], ShardId(assigned));
        assert_eq!(st.forwarded_requests, 1);
        assert_eq!(st.forwarded_responses, 1);

        drop(conn);
        gw.shutdown();
        s0.shutdown();
        s1.shutdown();
    }

    #[test]
    fn gateway_rejects_when_every_shard_is_down() {
        let s0 = sim_shard(0);
        let gw = gateway_over(&[&s0]);
        gw.set_shard_state(ShardId(0), ShardState::Down);

        let mut conn = TcpStream::connect(gw.addr).unwrap();
        write_msg(
            &mut conn,
            &Msg::Hello(Hello { client: 1, split: false, codec: 0, caps: 0, shard: None, epoch: None }),
        )
            .unwrap();
        // gateway closes without an ack
        assert!(matches!(read_msg(&mut conn), Ok(None) | Err(_)));
        // event-driven: woken the instant the connection thread counts it
        assert!(
            gw.wait_stats(Duration::from_secs(2), |s| s.rejected > 0),
            "rejection never counted"
        );
        gw.shutdown();
        s0.shutdown();
    }

    /// Bounded accept queue: a gateway at capacity sheds the connection
    /// with an explicit overload frame instead of silently hanging or
    /// queueing behind the batcher.
    #[test]
    fn over_capacity_connections_are_shed_with_an_explicit_overload_frame() {
        let s0 = sim_shard(0);
        let gw = serve_gateway(GatewayConfig {
            shards: vec![(ShardId(0), s0.addr)],
            max_conns: 0, // everything is over capacity
            ..GatewayConfig::default()
        })
        .expect("gateway");

        let mut conn = TcpStream::connect(gw.addr).unwrap();
        write_msg(
            &mut conn,
            &Msg::Hello(Hello { client: 9, split: false, codec: 0, caps: 0, shard: None, epoch: None }),
        )
        .unwrap();
        match read_msg(&mut conn).unwrap() {
            Some(Msg::Error(e)) => {
                assert_eq!(e.code, ERR_OVERLOADED);
                assert_eq!(e.client, 9);
            }
            other => panic!("expected an overload frame, got {other:?}"),
        }
        // and the connection is closed after the shed frame
        assert!(matches!(read_msg(&mut conn), Ok(None) | Err(_)));
        assert!(
            gw.wait_stats(Duration::from_secs(2), |s| s.shed_connections > 0),
            "shed never counted"
        );
        gw.shutdown();
        s0.shutdown();
    }

    /// Per-session rate cap: past the burst, requests are answered with
    /// an overload frame and never forwarded — but the session survives,
    /// so compliant traffic keeps flowing after backoff.
    #[test]
    fn rate_capped_requests_are_shed_without_killing_the_session() {
        let s0 = sim_shard(0);
        let gw = serve_gateway(GatewayConfig {
            shards: vec![(ShardId(0), s0.addr)],
            // one request of burst, then a refill far slower than the test
            rate_hz: 0.001,
            rate_burst: 1.0,
            ..GatewayConfig::default()
        })
        .expect("gateway");

        let mut conn = TcpStream::connect(gw.addr).unwrap();
        write_msg(
            &mut conn,
            &Msg::Hello(Hello { client: 3, split: false, codec: 0, caps: 0, shard: None, epoch: None }),
        )
        .unwrap();
        assert!(matches!(read_msg(&mut conn).unwrap().unwrap(), Msg::Hello(_)));

        let req = |id: u64| {
            Msg::Request(Request {
                client: 3,
                id,
                payload: Payload::RawRgba { x: 4, data: vec![1; 4 * 16] },
            })
        };
        // the burst token buys the first request a real response…
        write_msg(&mut conn, &req(1)).unwrap();
        loop {
            match read_msg(&mut conn).unwrap().unwrap() {
                Msg::Response(r) => {
                    assert_eq!(r.id, 1);
                    break;
                }
                _ => continue,
            }
        }
        // …and the second is shed with an explicit overload frame
        write_msg(&mut conn, &req(2)).unwrap();
        loop {
            match read_msg(&mut conn).unwrap().unwrap() {
                Msg::Error(e) => {
                    assert_eq!(e.code, ERR_OVERLOADED);
                    assert_eq!(e.client, 3);
                    break;
                }
                other => panic!("expected an overload frame, got {other:?}"),
            }
        }
        let st = gw.stats();
        assert_eq!(st.rate_limited, 1);
        assert_eq!(st.forwarded_requests, 1, "the shed request must not reach the shard");
        gw.shutdown();
        s0.shutdown();
    }

    #[test]
    fn unreachable_shard_is_marked_down_and_routed_around() {
        let live = sim_shard(0);
        // second loopback address: no parallel test can rebind this port
        let dead_addr = {
            let l = TcpListener::bind("127.0.0.2:0").unwrap();
            l.local_addr().unwrap()
        };
        let gw = serve_gateway(GatewayConfig {
            shards: vec![(ShardId(0), live.addr), (ShardId(1), dead_addr)],
            connect_timeout: Duration::from_millis(200),
            ..GatewayConfig::default()
        })
        .expect("gateway");

        // enough sessions that some hash onto the dead shard first
        for session in 0..32u32 {
            let mut conn = TcpStream::connect(gw.addr).unwrap();
            write_msg(
                &mut conn,
                &Msg::Hello(Hello {
                    client: session,
                    split: false,
                    codec: 0,
                    caps: 0,
                    shard: None,
                    epoch: None,
                }),
            )
            .unwrap();
            match read_msg(&mut conn).unwrap() {
                Some(Msg::Hello(h)) => assert_eq!(h.shard, Some(0), "landed on the dead shard"),
                other => panic!("no ack: {other:?}"),
            }
        }
        let states = gw.shard_states();
        let dead = states.iter().find(|(id, ..)| *id == ShardId(1)).unwrap();
        assert_eq!(dead.1, ShardState::Down);
        gw.shutdown();
        live.shutdown();
    }
}

//! Consistent-hash shard topology: the hash ring that pins sessions to
//! coordinator shards, plus the authoritative shard table with health /
//! draining states and live connection counts.
//!
//! Placement hashes only the 32-bit session id (the `client` field every
//! wire message carries), so a session's server-side state — its
//! `SessionManager` frame stack — stays on one shard across reconnects.
//! Each shard owns `vnodes` points on a 64-bit ring; removing a shard only
//! remaps the keys that lived on its points (the consistent-hashing
//! property the tests pin down).

use std::collections::BTreeMap;
use std::net::SocketAddr;

/// Stable shard identity within a fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShardId(pub u16);

impl std::fmt::Display for ShardId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shard-{}", self.0)
    }
}

/// Lifecycle of a shard as the gateway sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardState {
    /// healthy: accepts new sessions
    Up,
    /// responding slowly: still routable, flagged for operators
    Degraded,
    /// operator-initiated removal: existing connections keep flowing, new
    /// sessions route elsewhere; fully drained once its connections hit 0
    Draining,
    /// failed health checks or unreachable: not routable
    Down,
}

impl ShardState {
    /// May new sessions land here?
    pub fn routable(self) -> bool {
        matches!(self, ShardState::Up | ShardState::Degraded)
    }

    pub fn name(self) -> &'static str {
        match self {
            ShardState::Up => "up",
            ShardState::Degraded => "degraded",
            ShardState::Draining => "draining",
            ShardState::Down => "down",
        }
    }
}

/// One shard's entry in the fleet table.
#[derive(Debug, Clone)]
pub struct Shard {
    pub id: ShardId,
    pub addr: SocketAddr,
    pub state: ShardState,
    /// live gateway connections currently pinned here
    pub connections: usize,
}

/// splitmix64 finalizer — a well-mixed 64-bit hash for ring points and keys.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

fn ring_point(id: ShardId, vnode: usize) -> u64 {
    mix64(((id.0 as u64) << 32) ^ (vnode as u64) ^ 0x5EED_0F1E_E7A1_1CE5)
}

fn key_point(session: u32) -> u64 {
    mix64(session as u64 ^ 0xC1_1E57_0C0DE)
}

/// The ring itself: hash points -> shard, `vnodes` points per shard.
#[derive(Debug, Clone)]
pub struct HashRing {
    points: BTreeMap<u64, ShardId>,
    vnodes: usize,
}

impl HashRing {
    pub fn new(vnodes: usize) -> Self {
        assert!(vnodes > 0, "a ring needs at least one vnode per shard");
        HashRing { points: BTreeMap::new(), vnodes }
    }

    pub fn add(&mut self, id: ShardId) {
        for v in 0..self.vnodes {
            self.points.insert(ring_point(id, v), id);
        }
    }

    pub fn remove(&mut self, id: ShardId) {
        self.points.retain(|_, s| *s != id);
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// First shard clockwise of the session's hash for which `eligible`
    /// holds; None when no eligible shard exists.
    pub fn route_filtered<F: Fn(ShardId) -> bool>(
        &self,
        session: u32,
        eligible: F,
    ) -> Option<ShardId> {
        let h = key_point(session);
        self.points
            .range(h..)
            .chain(self.points.range(..h))
            .map(|(_, s)| *s)
            .find(|s| eligible(*s))
    }

    /// First shard clockwise of the session's hash.
    pub fn route(&self, session: u32) -> Option<ShardId> {
        self.route_filtered(session, |_| true)
    }
}

/// Authoritative fleet view: shard table + ring, shared (behind a mutex)
/// between the gateway's connection threads and the health monitor.
///
/// Every mutation that can change routing (add/remove/state) bumps the
/// topology `epoch`, a monotone u64 the gateway stamps into Hello acks so
/// clients and fuzzers can detect stale or forged re-route instructions
/// (DESIGN.md §10).
#[derive(Debug, Clone)]
pub struct Topology {
    shards: BTreeMap<ShardId, Shard>,
    ring: HashRing,
    epoch: u64,
}

impl Topology {
    pub fn new(vnodes: usize) -> Self {
        Topology { shards: BTreeMap::new(), ring: HashRing::new(vnodes), epoch: 0 }
    }

    /// Monotone routing-change counter: bumped by every add/remove/state
    /// mutation, never by connection accounting.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn add_shard(&mut self, id: ShardId, addr: SocketAddr) {
        self.shards
            .insert(id, Shard { id, addr, state: ShardState::Up, connections: 0 });
        self.ring.add(id);
        self.epoch += 1;
    }

    /// Drop a shard from the table and the ring entirely (use [`Self::drain`]
    /// for the graceful path).
    pub fn remove_shard(&mut self, id: ShardId) {
        self.shards.remove(&id);
        self.ring.remove(id);
        self.epoch += 1;
    }

    pub fn set_state(&mut self, id: ShardId, state: ShardState) {
        if let Some(s) = self.shards.get_mut(&id) {
            if s.state != state {
                s.state = state;
                self.epoch += 1;
            }
        }
    }

    /// Begin connection draining: keep serving pinned connections, stop
    /// accepting new sessions.
    pub fn drain(&mut self, id: ShardId) {
        self.set_state(id, ShardState::Draining);
    }

    /// A draining shard whose last pinned connection has closed.
    pub fn drained(&self, id: ShardId) -> bool {
        self.shards
            .get(&id)
            .is_some_and(|s| s.state == ShardState::Draining && s.connections == 0)
    }

    pub fn state(&self, id: ShardId) -> Option<ShardState> {
        self.shards.get(&id).map(|s| s.state)
    }

    pub fn shard(&self, id: ShardId) -> Option<&Shard> {
        self.shards.get(&id)
    }

    pub fn shards(&self) -> impl Iterator<Item = &Shard> {
        self.shards.values()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn n_routable(&self) -> usize {
        self.shards.values().filter(|s| s.state.routable()).count()
    }

    /// Consistent-hash placement among routable shards.
    pub fn route(&self, session: u32) -> Option<&Shard> {
        let id = self
            .ring
            .route_filtered(session, |s| {
                self.shards.get(&s).map(|sh| sh.state.routable()).unwrap_or(false)
            })?;
        self.shards.get(&id)
    }

    pub fn conn_opened(&mut self, id: ShardId) {
        if let Some(s) = self.shards.get_mut(&id) {
            s.connections += 1;
        }
    }

    pub fn conn_closed(&mut self, id: ShardId) {
        if let Some(s) = self.shards.get_mut(&id) {
            s.connections = s.connections.saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    fn topo(n: u16) -> Topology {
        let mut t = Topology::new(64);
        for i in 0..n {
            t.add_shard(ShardId(i), addr(9000 + i));
        }
        t
    }

    #[test]
    fn routing_is_deterministic() {
        let t = topo(4);
        for session in 0..200u32 {
            let a = t.route(session).unwrap().id;
            let b = t.route(session).unwrap().id;
            assert_eq!(a, b, "session {session} flapped");
        }
    }

    #[test]
    fn all_shards_receive_a_fair_share() {
        let t = topo(4);
        let mut counts = [0usize; 4];
        for session in 0..4000u32 {
            counts[t.route(session).unwrap().id.0 as usize] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            // perfect balance would be 1000; vnodes keep skew modest
            assert!(c > 400, "shard {i} starved: {counts:?}");
            assert!(c < 1800, "shard {i} overloaded: {counts:?}");
        }
    }

    #[test]
    fn removing_a_shard_only_remaps_its_own_sessions() {
        let t4 = topo(4);
        let mut t3 = t4.clone();
        t3.remove_shard(ShardId(3));
        let mut moved = 0;
        for session in 0..2000u32 {
            let before = t4.route(session).unwrap().id;
            let after = t3.route(session).unwrap().id;
            if before == ShardId(3) {
                assert_ne!(after, ShardId(3));
                moved += 1;
            } else {
                assert_eq!(before, after, "session {session} moved needlessly");
            }
        }
        assert!(moved > 0, "shard 3 owned no sessions?");
    }

    #[test]
    fn adding_a_shard_only_steals_its_own_keyspace() {
        // consistent-hashing property, add direction: growing the fleet
        // moves exactly the keys the new shard's ring points claim — every
        // other session keeps its assignment (no global reshuffle)
        let t4 = topo(4);
        let mut t5 = t4.clone();
        t5.add_shard(ShardId(4), addr(9004));
        let mut moved = 0;
        for session in 0..2000u32 {
            let before = t4.route(session).unwrap().id;
            let after = t5.route(session).unwrap().id;
            if after == ShardId(4) {
                moved += 1;
            } else {
                assert_eq!(before, after, "session {session} moved to a pre-existing shard");
            }
        }
        assert!(moved > 0, "new shard claimed no keyspace?");
        // with 5 shards the newcomer should take roughly 1/5th, not half
        assert!(moved < 1000, "new shard stole too much keyspace: {moved}/2000");
    }

    #[test]
    fn add_then_remove_restores_every_assignment() {
        // the ring has no hidden history: removing the shard that was just
        // added lands every key exactly where it started
        let t4 = topo(4);
        let mut t = t4.clone();
        t.add_shard(ShardId(9), addr(9009));
        t.remove_shard(ShardId(9));
        for session in 0..2000u32 {
            assert_eq!(t4.route(session).unwrap().id, t.route(session).unwrap().id);
        }
    }

    #[test]
    fn epoch_bumps_on_routing_changes_only() {
        let mut t = topo(2);
        let e0 = t.epoch();
        // connection accounting never moves the epoch
        t.conn_opened(ShardId(0));
        t.conn_closed(ShardId(0));
        assert_eq!(t.epoch(), e0);
        // a no-op state set (Up -> Up) is not a routing change
        t.set_state(ShardId(0), ShardState::Up);
        assert_eq!(t.epoch(), e0);
        t.drain(ShardId(0));
        assert_eq!(t.epoch(), e0 + 1);
        t.add_shard(ShardId(7), addr(9007));
        assert_eq!(t.epoch(), e0 + 2);
        t.remove_shard(ShardId(7));
        assert_eq!(t.epoch(), e0 + 3);
        // unknown shard ids are ignored, epoch included
        t.set_state(ShardId(42), ShardState::Down);
        assert_eq!(t.epoch(), e0 + 3);
    }

    #[test]
    fn draining_and_down_shards_get_no_new_sessions() {
        let mut t = topo(3);
        t.drain(ShardId(0));
        t.set_state(ShardId(1), ShardState::Down);
        for session in 0..500u32 {
            assert_eq!(t.route(session).unwrap().id, ShardId(2));
        }
        assert_eq!(t.n_routable(), 1);
        // degraded stays routable
        t.set_state(ShardId(2), ShardState::Degraded);
        assert!(t.route(7).is_some());
    }

    #[test]
    fn drained_requires_zero_connections() {
        let mut t = topo(2);
        t.conn_opened(ShardId(0));
        t.drain(ShardId(0));
        assert!(!t.drained(ShardId(0)));
        t.conn_closed(ShardId(0));
        assert!(t.drained(ShardId(0)));
        // an up shard is never "drained"
        assert!(!t.drained(ShardId(1)));
    }

    #[test]
    fn empty_or_fully_down_topology_routes_nowhere() {
        let t = Topology::new(8);
        assert!(t.route(1).is_none());
        let mut t = topo(2);
        t.set_state(ShardId(0), ShardState::Down);
        t.set_state(ShardId(1), ShardState::Down);
        assert!(t.route(1).is_none());
    }

    #[test]
    fn reconnecting_session_lands_on_the_same_shard_across_clones() {
        // the gateway consults a fresh lock-guarded view per connection;
        // placement must be a pure function of (topology, session)
        let t = topo(5);
        let u = t.clone();
        for session in [0u32, 1, 42, 7_000_000, u32::MAX] {
            assert_eq!(t.route(session).unwrap().id, u.route(session).unwrap().id);
        }
    }
}

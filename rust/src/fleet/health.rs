//! Shard health: `Hello` round-trip probes over the real wire protocol.
//!
//! Every shard acks a `Hello` frame with its shard id (coordinator reader
//! behaviour), so a probe is connect → hello → await ack. The monitor
//! thread probes each shard on an interval and edits the shared
//! [`Topology`]: consecutive failures mark a shard `Down` (new sessions
//! route around it), slow acks mark it `Degraded`, and a recovered shard
//! returns to `Up`. Operator intent is respected: a `Draining` shard is
//! probed but never re-stated.
//!
//! The verdict→state step is the pure [`probe_transition`] function: the
//! threaded monitor applies it to wall-clock probe outcomes, and the
//! simnet's virtual-time prober (`sim::scenario`) applies the *same*
//! function to simulated outcomes — one state machine, two time sources.
//! Observers never poll: the monitor notifies a [`Signal`] after every
//! probe verdict, and [`HealthMonitor::wait_topology`] blocks until a
//! predicate over the topology holds.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};
use log::{debug, warn};

use crate::net::framing::{Hello, Msg};
use crate::net::tcp::{read_msg, write_msg};
use crate::util::signal::Signal;

use super::topology::{ShardId, ShardState, Topology};

/// Reserved session id for health probes (never creates server-side state:
/// a `Hello` alone touches no `SessionManager` entry).
pub const PROBE_CLIENT: u32 = u32::MAX;

#[derive(Debug, Clone)]
pub struct HealthConfig {
    /// time between probe rounds
    pub interval: Duration,
    /// connect + ack deadline per probe
    pub timeout: Duration,
    /// consecutive failures before a shard is marked Down
    pub fail_threshold: u32,
    /// ack RTT above this marks a shard Degraded
    pub degraded_after: Duration,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            interval: Duration::from_millis(250),
            timeout: Duration::from_millis(500),
            fail_threshold: 2,
            degraded_after: Duration::from_millis(50),
        }
    }
}

/// Per-shard probe bookkeeping, cloneable for reports.
#[derive(Debug, Clone, Default)]
pub struct ProbeStats {
    pub probes: u64,
    pub failures: u64,
    pub consecutive_failures: u32,
    /// last successful round trip, seconds
    pub last_rtt: Option<f64>,
}

/// The pure probe-verdict state machine: given a shard's current state,
/// the latest probe outcome (`Some(rtt)` on success), and the consecutive
/// failure count *including* this outcome, decide the next state (None =
/// no change). Draining is sacred — operator intent wins over probe
/// evidence in every case.
pub fn probe_transition(
    current: ShardState,
    rtt: Option<Duration>,
    consecutive_failures: u32,
    cfg: &HealthConfig,
) -> Option<ShardState> {
    if current == ShardState::Draining {
        return None;
    }
    match rtt {
        Some(rtt) => {
            let next = if rtt > cfg.degraded_after {
                ShardState::Degraded
            } else {
                ShardState::Up
            };
            (current != next).then_some(next)
        }
        None => (consecutive_failures >= cfg.fail_threshold && current != ShardState::Down)
            .then_some(ShardState::Down),
    }
}

/// One blocking probe: connect, hello, await the shard's hello ack.
/// Returns the round-trip time and the shard id the ack carried.
pub fn probe_shard(addr: SocketAddr, timeout: Duration) -> Result<(Duration, Option<u16>)> {
    let t0 = Instant::now();
    let mut stream = TcpStream::connect_timeout(&addr, timeout)
        .with_context(|| format!("probe connect {addr}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout)).ok();
    write_msg(
        &mut stream,
        &Msg::Hello(Hello { client: PROBE_CLIENT, split: false, codec: 0, caps: 0, shard: None, epoch: None }),
    )?;
    loop {
        match read_msg(&mut stream)? {
            Some(Msg::Hello(h)) => return Ok((t0.elapsed(), h.shard)),
            Some(_) => continue, // stray traffic on a fresh connection
            None => bail!("shard {addr} closed before acking the probe"),
        }
    }
}

/// Background prober that keeps a shared [`Topology`] honest.
pub struct HealthMonitor {
    shutdown: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<HashMap<ShardId, ProbeStats>>>,
    topology: Arc<Mutex<Topology>>,
    signal: Arc<Signal>,
}

impl HealthMonitor {
    pub fn start(topology: Arc<Mutex<Topology>>, cfg: HealthConfig) -> HealthMonitor {
        Self::start_with(topology, cfg, Arc::new(Signal::new()))
    }

    /// Start against a caller-provided change [`Signal`] — the gateway
    /// shares one signal between its own stats edits and the monitor's
    /// topology edits, so a single wait observes both.
    pub fn start_with(
        topology: Arc<Mutex<Topology>>,
        cfg: HealthConfig,
        signal: Arc<Signal>,
    ) -> HealthMonitor {
        let shutdown = Arc::new(AtomicBool::new(false));
        let stats: Arc<Mutex<HashMap<ShardId, ProbeStats>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let t_shutdown = shutdown.clone();
        let t_stats = stats.clone();
        let t_topology = topology.clone();
        let t_signal = signal.clone();
        let thread = std::thread::Builder::new()
            .name("mc-health".into())
            .spawn(move || monitor_main(t_topology, cfg, t_shutdown, t_stats, t_signal))
            .expect("spawn health monitor");
        HealthMonitor { shutdown, thread: Some(thread), stats, topology, signal }
    }

    /// Snapshot of per-shard probe stats.
    pub fn stats(&self) -> HashMap<ShardId, ProbeStats> {
        self.stats.lock().unwrap().clone()
    }

    /// The change signal: notified after every probe verdict.
    pub fn signal(&self) -> &Arc<Signal> {
        &self.signal
    }

    /// Block until `pred` holds over the shared topology (re-checked after
    /// every probe verdict) or `timeout` elapses; returns the final
    /// verdict. The event-driven replacement for sleep-poll loops.
    pub fn wait_topology<F: Fn(&Topology) -> bool>(&self, timeout: Duration, pred: F) -> bool {
        let top = self.topology.clone();
        self.signal.wait_until(timeout, || pred(&top.lock().unwrap()))
    }

    /// Block until `pred` holds over the probe stats, or `timeout`.
    pub fn wait_stats<F: Fn(&HashMap<ShardId, ProbeStats>) -> bool>(
        &self,
        timeout: Duration,
        pred: F,
    ) -> bool {
        let stats = self.stats.clone();
        self.signal.wait_until(timeout, || pred(&stats.lock().unwrap()))
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // wake the interval wait instantly — no sleep-slice latency
        self.signal.notify();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn monitor_main(
    topology: Arc<Mutex<Topology>>,
    cfg: HealthConfig,
    shutdown: Arc<AtomicBool>,
    stats: Arc<Mutex<HashMap<ShardId, ProbeStats>>>,
    signal: Arc<Signal>,
) {
    while !shutdown.load(Ordering::SeqCst) {
        // snapshot targets without holding the lock across probes
        let targets: Vec<(ShardId, SocketAddr)> = {
            let top = topology.lock().unwrap();
            top.shards().map(|s| (s.id, s.addr)).collect()
        };
        for (id, addr) in targets {
            if shutdown.load(Ordering::SeqCst) {
                return;
            }
            let outcome = probe_shard(addr, cfg.timeout);
            let consecutive = {
                let mut st = stats.lock().unwrap();
                let e = st.entry(id).or_default();
                e.probes += 1;
                match &outcome {
                    Ok((rtt, _)) => {
                        e.consecutive_failures = 0;
                        e.last_rtt = Some(rtt.as_secs_f64());
                    }
                    Err(_) => {
                        e.failures += 1;
                        e.consecutive_failures += 1;
                    }
                }
                e.consecutive_failures
            };
            let rtt = match outcome {
                Ok((rtt, _)) => Some(rtt),
                Err(e) => {
                    debug!("health: probe {id} failed: {e:#}");
                    None
                }
            };
            {
                let mut top = topology.lock().unwrap();
                let Some(state) = top.state(id) else { continue };
                if let Some(next) = probe_transition(state, rtt, consecutive, &cfg) {
                    match next {
                        ShardState::Down => {
                            warn!("health: {id} marked down after {consecutive} failures")
                        }
                        _ if state == ShardState::Down => {
                            let ms = rtt.unwrap_or_default().as_secs_f64() * 1e3;
                            warn!("health: {id} recovered ({ms:.1} ms)");
                        }
                        _ => {}
                    }
                    top.set_state(id, next);
                }
            }
            // topology lock released: announce the verdict to waiters
            signal.notify();
        }
        // event-driven interval: wakes instantly when stop() notifies
        signal.wait_until(cfg.interval, || shutdown.load(Ordering::SeqCst));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{serve, Backend, ServerConfig, SimSpec};

    fn sim_server(shard_id: u16) -> crate::coordinator::ServerHandle {
        serve(ServerConfig {
            shard_id: Some(shard_id),
            backend: Backend::Sim(SimSpec::default()),
            ..ServerConfig::default()
        })
        .expect("sim server")
    }

    /// An address that refuses connections. Allocated on a second loopback
    /// address no test ever listens on, so a parallel test binding
    /// `127.0.0.1:0` can never be handed the just-freed port and turn the
    /// "dead" endpoint live.
    fn dead_addr() -> SocketAddr {
        let l = std::net::TcpListener::bind("127.0.0.2:0").unwrap();
        l.local_addr().unwrap()
    }

    fn cfg_ms(interval: u64, timeout: u64, fail_threshold: u32) -> HealthConfig {
        HealthConfig {
            interval: Duration::from_millis(interval),
            timeout: Duration::from_millis(timeout),
            fail_threshold,
            // generous: a loopback hello ack must never look degraded
            degraded_after: Duration::from_secs(5),
        }
    }

    #[test]
    fn transition_function_is_the_documented_state_machine() {
        let cfg = HealthConfig { fail_threshold: 2, ..HealthConfig::default() };
        let fast = Some(Duration::from_millis(1));
        let slow = Some(Duration::from_secs(1));
        use ShardState::*;
        // successes
        assert_eq!(probe_transition(Up, fast, 0, &cfg), None);
        assert_eq!(probe_transition(Up, slow, 0, &cfg), Some(Degraded));
        assert_eq!(probe_transition(Degraded, fast, 0, &cfg), Some(Up));
        assert_eq!(probe_transition(Down, fast, 0, &cfg), Some(Up));
        // failures: threshold gates the Down edge
        assert_eq!(probe_transition(Up, None, 1, &cfg), None);
        assert_eq!(probe_transition(Up, None, 2, &cfg), Some(Down));
        assert_eq!(probe_transition(Down, None, 9, &cfg), None);
        // draining is never re-stated, by success or failure
        assert_eq!(probe_transition(Draining, fast, 0, &cfg), None);
        assert_eq!(probe_transition(Draining, None, 99, &cfg), None);
    }

    #[test]
    fn probe_round_trips_and_reports_shard_id() {
        let server = sim_server(7);
        let (rtt, shard) = probe_shard(server.addr, Duration::from_secs(2)).expect("probe");
        assert_eq!(shard, Some(7));
        assert!(rtt < Duration::from_secs(1));
        server.shutdown();
    }

    #[test]
    fn probe_fails_fast_against_a_dead_port() {
        assert!(probe_shard(dead_addr(), Duration::from_millis(300)).is_err());
    }

    #[test]
    fn monitor_marks_dead_shard_down_and_leaves_live_one_up() {
        let live = sim_server(0);
        let topology = Arc::new(Mutex::new(Topology::new(16)));
        {
            let mut t = topology.lock().unwrap();
            t.add_shard(ShardId(0), live.addr);
            t.add_shard(ShardId(1), dead_addr());
        }
        let monitor = HealthMonitor::start(topology.clone(), cfg_ms(30, 200, 2));
        // event-driven: woken on every probe verdict, no sleep-polling
        let converged = monitor.wait_topology(Duration::from_secs(5), |t| {
            t.state(ShardId(1)) == Some(ShardState::Down)
                && t.state(ShardId(0)) == Some(ShardState::Up)
        });
        assert!(converged, "monitor never converged: {:?}", monitor.stats());
        let stats = monitor.stats();
        assert!(stats[&ShardId(1)].failures >= 2);
        assert!(stats[&ShardId(0)].last_rtt.is_some());
        monitor.stop();
        live.shutdown();
    }

    #[test]
    fn monitor_never_overrides_draining() {
        let topology = Arc::new(Mutex::new(Topology::new(16)));
        {
            let mut t = topology.lock().unwrap();
            t.add_shard(ShardId(0), dead_addr());
            t.drain(ShardId(0));
        }
        let monitor = HealthMonitor::start(topology.clone(), cfg_ms(20, 100, 1));
        // wait for hard evidence the threshold was crossed repeatedly —
        // not for wall time to pass
        let probed = monitor.wait_stats(Duration::from_secs(5), |s| {
            s.get(&ShardId(0)).is_some_and(|e| e.consecutive_failures >= 3)
        });
        assert!(probed, "monitor never probed the drained shard");
        assert_eq!(
            topology.lock().unwrap().state(ShardId(0)),
            Some(ShardState::Draining),
            "probe evidence overrode operator draining"
        );
        monitor.stop();
    }
}

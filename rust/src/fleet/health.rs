//! Shard health: `Hello` round-trip probes over the real wire protocol.
//!
//! Every shard acks a `Hello` frame with its shard id (coordinator reader
//! behaviour), so a probe is connect → hello → await ack. The monitor
//! thread probes each shard on an interval and edits the shared
//! [`Topology`]: consecutive failures mark a shard `Down` (new sessions
//! route around it), slow acks mark it `Degraded`, and a recovered shard
//! returns to `Up`. Operator intent is respected: a `Draining` shard is
//! probed but never re-stated.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};
use log::{debug, warn};

use crate::net::framing::{Hello, Msg};
use crate::net::tcp::{read_msg, write_msg};

use super::topology::{ShardId, ShardState, Topology};

/// Reserved session id for health probes (never creates server-side state:
/// a `Hello` alone touches no `SessionManager` entry).
pub const PROBE_CLIENT: u32 = u32::MAX;

#[derive(Debug, Clone)]
pub struct HealthConfig {
    /// time between probe rounds
    pub interval: Duration,
    /// connect + ack deadline per probe
    pub timeout: Duration,
    /// consecutive failures before a shard is marked Down
    pub fail_threshold: u32,
    /// ack RTT above this marks a shard Degraded
    pub degraded_after: Duration,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            interval: Duration::from_millis(250),
            timeout: Duration::from_millis(500),
            fail_threshold: 2,
            degraded_after: Duration::from_millis(50),
        }
    }
}

/// Per-shard probe bookkeeping, cloneable for reports.
#[derive(Debug, Clone, Default)]
pub struct ProbeStats {
    pub probes: u64,
    pub failures: u64,
    pub consecutive_failures: u32,
    /// last successful round trip, seconds
    pub last_rtt: Option<f64>,
}

/// One blocking probe: connect, hello, await the shard's hello ack.
/// Returns the round-trip time and the shard id the ack carried.
pub fn probe_shard(addr: SocketAddr, timeout: Duration) -> Result<(Duration, Option<u16>)> {
    let t0 = Instant::now();
    let mut stream = TcpStream::connect_timeout(&addr, timeout)
        .with_context(|| format!("probe connect {addr}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout)).ok();
    write_msg(
        &mut stream,
        &Msg::Hello(Hello { client: PROBE_CLIENT, split: false, shard: None }),
    )?;
    loop {
        match read_msg(&mut stream)? {
            Some(Msg::Hello(h)) => return Ok((t0.elapsed(), h.shard)),
            Some(_) => continue, // stray traffic on a fresh connection
            None => bail!("shard {addr} closed before acking the probe"),
        }
    }
}

/// Background prober that keeps a shared [`Topology`] honest.
pub struct HealthMonitor {
    shutdown: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<HashMap<ShardId, ProbeStats>>>,
}

impl HealthMonitor {
    pub fn start(topology: Arc<Mutex<Topology>>, cfg: HealthConfig) -> HealthMonitor {
        let shutdown = Arc::new(AtomicBool::new(false));
        let stats: Arc<Mutex<HashMap<ShardId, ProbeStats>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let t_shutdown = shutdown.clone();
        let t_stats = stats.clone();
        let thread = std::thread::Builder::new()
            .name("mc-health".into())
            .spawn(move || monitor_main(topology, cfg, t_shutdown, t_stats))
            .expect("spawn health monitor");
        HealthMonitor { shutdown, thread: Some(thread), stats }
    }

    /// Snapshot of per-shard probe stats.
    pub fn stats(&self) -> HashMap<ShardId, ProbeStats> {
        self.stats.lock().unwrap().clone()
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn monitor_main(
    topology: Arc<Mutex<Topology>>,
    cfg: HealthConfig,
    shutdown: Arc<AtomicBool>,
    stats: Arc<Mutex<HashMap<ShardId, ProbeStats>>>,
) {
    while !shutdown.load(Ordering::SeqCst) {
        // snapshot targets without holding the lock across probes
        let targets: Vec<(ShardId, SocketAddr)> = {
            let top = topology.lock().unwrap();
            top.shards().map(|s| (s.id, s.addr)).collect()
        };
        for (id, addr) in targets {
            if shutdown.load(Ordering::SeqCst) {
                return;
            }
            let outcome = probe_shard(addr, cfg.timeout);
            let consecutive = {
                let mut st = stats.lock().unwrap();
                let e = st.entry(id).or_default();
                e.probes += 1;
                match &outcome {
                    Ok((rtt, _)) => {
                        e.consecutive_failures = 0;
                        e.last_rtt = Some(rtt.as_secs_f64());
                    }
                    Err(_) => {
                        e.failures += 1;
                        e.consecutive_failures += 1;
                    }
                }
                e.consecutive_failures
            };
            let mut top = topology.lock().unwrap();
            let Some(state) = top.state(id) else { continue };
            if state == ShardState::Draining {
                continue; // operator intent wins over probe evidence
            }
            match outcome {
                Ok((rtt, _)) => {
                    let next = if rtt > cfg.degraded_after {
                        ShardState::Degraded
                    } else {
                        ShardState::Up
                    };
                    if state != next {
                        if state == ShardState::Down {
                            warn!("health: {id} recovered ({:.1} ms)", rtt.as_secs_f64() * 1e3);
                        }
                        top.set_state(id, next);
                    }
                }
                Err(e) => {
                    debug!("health: probe {id} failed: {e:#}");
                    if consecutive >= cfg.fail_threshold && state != ShardState::Down {
                        warn!("health: {id} marked down after {consecutive} failures");
                        top.set_state(id, ShardState::Down);
                    }
                }
            }
        }
        // sleep in small slices so stop() stays responsive
        let mut left = cfg.interval;
        while !left.is_zero() && !shutdown.load(Ordering::SeqCst) {
            let step = left.min(Duration::from_millis(25));
            std::thread::sleep(step);
            left -= step;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{serve, Backend, ServerConfig, SimSpec};

    fn sim_server(shard_id: u16) -> crate::coordinator::ServerHandle {
        serve(ServerConfig {
            shard_id: Some(shard_id),
            backend: Backend::Sim(SimSpec::default()),
            ..ServerConfig::default()
        })
        .expect("sim server")
    }

    /// An address that refuses connections. Allocated on a second loopback
    /// address no test ever listens on, so a parallel test binding
    /// `127.0.0.1:0` can never be handed the just-freed port and turn the
    /// "dead" endpoint live.
    fn dead_addr() -> SocketAddr {
        let l = std::net::TcpListener::bind("127.0.0.2:0").unwrap();
        l.local_addr().unwrap()
    }

    #[test]
    fn probe_round_trips_and_reports_shard_id() {
        let server = sim_server(7);
        let (rtt, shard) = probe_shard(server.addr, Duration::from_secs(2)).expect("probe");
        assert_eq!(shard, Some(7));
        assert!(rtt < Duration::from_secs(1));
        server.shutdown();
    }

    #[test]
    fn probe_fails_fast_against_a_dead_port() {
        assert!(probe_shard(dead_addr(), Duration::from_millis(300)).is_err());
    }

    #[test]
    fn monitor_marks_dead_shard_down_and_leaves_live_one_up() {
        let live = sim_server(0);
        let topology = Arc::new(Mutex::new(Topology::new(16)));
        {
            let mut t = topology.lock().unwrap();
            t.add_shard(ShardId(0), live.addr);
            t.add_shard(ShardId(1), dead_addr());
        }
        let monitor = HealthMonitor::start(
            topology.clone(),
            HealthConfig {
                interval: Duration::from_millis(30),
                timeout: Duration::from_millis(200),
                fail_threshold: 2,
                // generous: a loopback hello ack must never look degraded
                degraded_after: Duration::from_secs(5),
            },
        );
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let (s0, s1) = {
                let t = topology.lock().unwrap();
                (t.state(ShardId(0)).unwrap(), t.state(ShardId(1)).unwrap())
            };
            if s1 == ShardState::Down && s0 == ShardState::Up {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "monitor never converged: shard0={s0:?} shard1={s1:?}"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
        let stats = monitor.stats();
        assert!(stats[&ShardId(1)].failures >= 2);
        assert!(stats[&ShardId(0)].last_rtt.is_some());
        monitor.stop();
        live.shutdown();
    }

    #[test]
    fn monitor_never_overrides_draining() {
        let topology = Arc::new(Mutex::new(Topology::new(16)));
        {
            let mut t = topology.lock().unwrap();
            t.add_shard(ShardId(0), dead_addr());
            t.drain(ShardId(0));
        }
        let monitor = HealthMonitor::start(
            topology.clone(),
            HealthConfig {
                interval: Duration::from_millis(20),
                timeout: Duration::from_millis(100),
                fail_threshold: 1,
                ..HealthConfig::default()
            },
        );
        std::thread::sleep(Duration::from_millis(400));
        assert_eq!(
            topology.lock().unwrap().state(ShardId(0)),
            Some(ShardState::Draining),
            "probe evidence overrode operator draining"
        );
        monitor.stop();
    }
}

//! Length-prefixed message transport over any Read/Write pair (used with
//! loopback TCP in the serving experiments; composes with
//! [`super::shaped::ShapedWriter`] for bandwidth-shaped links).

use std::io::{Read, Write};

use anyhow::{ensure, Context, Result};

use super::framing::{Msg, MAX_FRAME};
use super::limits::FrameLimits;

/// Write one message (blocking).
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<()> {
    let frame = msg.encode();
    write_frame(w, &frame)
}

/// Write an already-encoded frame (length prefix included), e.g. one built
/// by `Msg::encode_into` or `framing::encode_response_into` — the pooled
/// reply path writes straight from the reused buffer.
pub fn write_frame<W: Write>(w: &mut W, frame: &[u8]) -> Result<()> {
    w.write_all(frame).context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one length-prefixed frame body (type byte + payload) into a
/// caller-owned buffer without decoding it — the gateway's forwarding path
/// copies frames verbatim instead of decode/re-encode round trips.
/// Returns Ok(false) on clean EOF at a frame boundary.
pub fn read_raw_frame<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> Result<bool> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(false),
        Err(e) => return Err(e).context("reading frame length"),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    ensure!(len > 0 && len <= MAX_FRAME, "bad frame length {len}");
    buf.clear();
    buf.resize(len, 0);
    r.read_exact(buf.as_mut_slice()).context("reading frame body")?;
    Ok(true)
}

/// [`read_raw_frame`] with per-message-type size caps (`net::limits`,
/// DESIGN.md §9): the claimed length is checked against the hard ceiling,
/// then the one type byte is read and the length re-checked against that
/// type's cap — all *before* the body buys an allocation. A violation is
/// an error, and the caller must treat it as fatal for the connection
/// (the body bytes are unread, so framing is out of sync); untrusted
/// readers (server, gateway) disconnect, which is the point.
pub fn read_raw_frame_limited<R: Read>(
    r: &mut R,
    buf: &mut Vec<u8>,
    limits: &FrameLimits,
) -> Result<bool> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(false),
        Err(e) => return Err(e).context("reading frame length"),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    ensure!(len > 0 && len <= limits.hard_max(), "bad frame length {len}");
    let mut ty = [0u8; 1];
    r.read_exact(&mut ty).context("reading frame type")?;
    let cap = limits.cap(ty[0]);
    ensure!(
        len <= cap,
        "frame type {} claims {len} bytes (cap {cap})",
        ty[0]
    );
    buf.clear();
    buf.resize(len, 0);
    buf[0] = ty[0];
    r.read_exact(&mut buf[1..]).context("reading frame body")?;
    Ok(true)
}

/// Write a frame body previously read by [`read_raw_frame`] (re-adds the
/// length prefix; the body bytes are never re-encoded).
pub fn write_raw_frame<W: Write>(w: &mut W, body: &[u8]) -> Result<()> {
    let len = body.len() as u32;
    w.write_all(&len.to_le_bytes()).context("writing frame length")?;
    w.write_all(body).context("writing frame body")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one message (blocking). Returns Ok(None) on clean EOF at a frame
/// boundary.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Option<Msg>> {
    let mut body = Vec::new();
    if !read_raw_frame(r, &mut body)? {
        return Ok(None);
    }
    Ok(Some(Msg::decode(&body)?))
}

/// [`read_msg`] under per-type frame caps, split into transport and
/// decode outcomes so callers can budget malformed frames separately
/// from framing violations:
///
///   * `Ok(None)` — clean EOF;
///   * `Ok(Some(Err(_)))` — the frame was admitted and fully read but
///     does not decode. Framing is still synchronized: the caller may
///     count it against the session's decode-error budget and continue;
///   * `Err(_)` — a transport-level violation (oversize claim, unknown
///     type, torn read): the connection must be dropped.
pub fn read_msg_limited<R: Read>(
    r: &mut R,
    buf: &mut Vec<u8>,
    limits: &FrameLimits,
) -> Result<Option<Result<Msg>>> {
    if !read_raw_frame_limited(r, buf, limits)? {
        return Ok(None);
    }
    Ok(Some(Msg::decode(buf)))
}

/// [`read_msg_limited`] for sessions that may have negotiated `CAP_TRACE`
/// (DESIGN.md §12). With `traced` set, every trace-eligible frame MUST end
/// in the fixed per-decision trace trailer, which is peeled off before the
/// canonical decode and handed back alongside the message; a missing or
/// malformed trailer is a decode error (budgeted against the session like
/// any other undecodable body — framing stays synchronized). Ineligible
/// types, and every frame on an untraced session, decode exactly as
/// [`read_msg_limited`] with `None` for the context.
pub fn read_msg_traced<R: Read>(
    r: &mut R,
    buf: &mut Vec<u8>,
    limits: &FrameLimits,
    traced: bool,
) -> Result<Option<Result<(Msg, Option<crate::trace::TraceCtx>)>>> {
    if !read_raw_frame_limited(r, buf, limits)? {
        return Ok(None);
    }
    if traced && !buf.is_empty() && crate::trace::trace_eligible(buf[0]) {
        let res = crate::trace::split_trailer(buf)
            .and_then(|(inner, ctx)| Msg::decode(inner).map(|m| (m, Some(ctx))));
        return Ok(Some(res));
    }
    Ok(Some(Msg::decode(buf).map(|m| (m, None))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::framing::{Hello, Payload, Request, Response};

    #[test]
    fn roundtrip_over_a_buffer() {
        let msgs = vec![
            Msg::Hello(Hello { client: 1, split: true, codec: 0, caps: 0, shard: None, epoch: None }),
            Msg::Request(Request {
                client: 1,
                id: 1,
                payload: Payload::Features {
                    c: 4,
                    h: 11,
                    w: 11,
                    scale: 2.0,
                    data: vec![9; 484],
                },
            }),
            Msg::Response(Response { client: 1, id: 1, action: vec![0.25] }),
        ];
        let mut wire = Vec::new();
        for m in &msgs {
            write_msg(&mut wire, m).unwrap();
        }
        let mut cursor = std::io::Cursor::new(wire);
        for m in &msgs {
            assert_eq!(&read_msg(&mut cursor).unwrap().unwrap(), m);
        }
        assert!(read_msg(&mut cursor).unwrap().is_none()); // clean EOF
    }

    #[test]
    fn raw_frame_roundtrip_preserves_bytes_and_reuses_buffer() {
        let msg = Msg::Request(Request {
            client: 8,
            id: 21,
            payload: Payload::Features { c: 4, h: 2, w: 2, scale: 1.25, data: vec![7; 16] },
        });
        let mut wire = Vec::new();
        write_msg(&mut wire, &msg).unwrap();
        write_msg(&mut wire, &Msg::Response(Response { client: 8, id: 21, action: vec![1.0] }))
            .unwrap();

        let mut cursor = std::io::Cursor::new(&wire);
        let mut buf = Vec::new();
        let mut forwarded = Vec::new();
        while read_raw_frame(&mut cursor, &mut buf).unwrap() {
            write_raw_frame(&mut forwarded, &buf).unwrap();
        }
        // verbatim copy: the forwarded stream is byte-identical
        assert_eq!(forwarded, wire);
        // and decodes to the original messages
        let mut cursor = std::io::Cursor::new(forwarded);
        assert_eq!(read_msg(&mut cursor).unwrap().unwrap(), msg);
        assert!(matches!(read_msg(&mut cursor).unwrap().unwrap(), Msg::Response(_)));
    }

    #[test]
    fn write_frame_matches_write_msg() {
        let msg = Msg::Hello(Hello { client: 2, split: true, codec: 1, caps: 0, shard: Some(1), epoch: None });
        let mut a = Vec::new();
        write_msg(&mut a, &msg).unwrap();
        let mut b = Vec::new();
        let mut frame = Vec::new();
        msg.encode_into(&mut frame);
        write_frame(&mut b, &frame).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_oversized_frame() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(u32::MAX).to_le_bytes());
        wire.push(1);
        let mut cursor = std::io::Cursor::new(wire);
        assert!(read_msg(&mut cursor).is_err());
    }

    #[test]
    fn limited_reader_enforces_per_type_caps_before_allocating() {
        use crate::net::limits::{FrameLimits, LimitsConfig};
        let cfg = LimitsConfig { max_obs_x: 4, ..LimitsConfig::default() };
        let limits = FrameLimits::pre_hello(&cfg);
        // a 6×6 raw request exceeds the 4-pixel cap…
        let over = Msg::Request(Request {
            client: 1,
            id: 1,
            payload: Payload::RawRgba { x: 6, data: vec![0; 4 * 36] },
        })
        .encode();
        let mut buf = Vec::new();
        assert!(read_raw_frame_limited(&mut std::io::Cursor::new(&over), &mut buf, &limits)
            .is_err());
        // …while a 4×4 one passes and round-trips byte-identically
        let ok = Msg::Request(Request {
            client: 1,
            id: 2,
            payload: Payload::RawRgba { x: 4, data: vec![7; 4 * 16] },
        })
        .encode();
        assert!(read_raw_frame_limited(&mut std::io::Cursor::new(&ok), &mut buf, &limits)
            .unwrap());
        assert_eq!(buf, ok[4..]);
        // unknown type ids are rejected before any body read
        let mut junk = Vec::new();
        junk.extend_from_slice(&2u32.to_le_bytes());
        junk.extend_from_slice(&[200, 0]);
        assert!(read_raw_frame_limited(&mut std::io::Cursor::new(&junk), &mut buf, &limits)
            .is_err());
    }

    #[test]
    fn limited_read_msg_separates_framing_violations_from_decode_errors() {
        use crate::net::limits::{FrameLimits, LimitsConfig};
        let limits = FrameLimits::pre_hello(&LimitsConfig::default());
        let mut buf = Vec::new();
        // well-framed hello with a torn payload: admitted, fails decode,
        // and the stream stays synchronized for the next frame
        let mut wire = Vec::new();
        wire.extend_from_slice(&3u32.to_le_bytes());
        wire.extend_from_slice(&[crate::net::framing::MSG_HELLO, 1, 2]);
        write_msg(&mut wire, &Msg::Response(Response { client: 1, id: 7, action: vec![] }))
            .unwrap();
        let mut cursor = std::io::Cursor::new(wire);
        let first = read_msg_limited(&mut cursor, &mut buf, &limits).unwrap().unwrap();
        assert!(first.is_err(), "torn hello must fail decode, not framing");
        let second = read_msg_limited(&mut cursor, &mut buf, &limits).unwrap().unwrap();
        assert!(matches!(second.unwrap(), Msg::Response(r) if r.id == 7));
        assert!(read_msg_limited(&mut cursor, &mut buf, &limits).unwrap().is_none());
        // a 64 MiB claim the permissive reader tolerates is a transport
        // error here
        let mut big = Vec::new();
        big.extend_from_slice(&(crate::net::framing::MAX_FRAME as u32).to_le_bytes());
        big.push(crate::net::framing::MSG_REQUEST_RAW);
        assert!(read_msg_limited(&mut std::io::Cursor::new(big), &mut buf, &limits).is_err());
    }

    #[test]
    fn traced_reader_peels_trailers_and_budgets_missing_ones() {
        use crate::net::limits::{FrameLimits, LimitsConfig};
        use crate::trace::{TraceCtx, STAGE_SEND};
        let mut limits = FrameLimits::negotiated(false, &LimitsConfig::default());
        limits.allow_trace();
        let msg = Msg::Request(Request {
            client: 2,
            id: 5,
            payload: Payload::RawRgba { x: 2, data: vec![1; 16] },
        });
        let mut ctx = TraceCtx::mint(0xbeef, 100);
        ctx.stamp(STAGE_SEND, 140);
        let mut frame = msg.encode();
        crate::trace::append_trace(&mut frame, &ctx);
        let hello = Msg::Hello(Hello { client: 2, split: false, codec: 0, caps: 0, shard: None, epoch: None });
        let mut wire = frame.clone();
        write_msg(&mut wire, &hello).unwrap(); // ineligible: never carries a trailer
        write_msg(&mut wire, &msg).unwrap(); // eligible but traceless: decode error when traced

        let mut cursor = std::io::Cursor::new(&wire);
        let mut buf = Vec::new();
        let (got, t) =
            read_msg_traced(&mut cursor, &mut buf, &limits, true).unwrap().unwrap().unwrap();
        assert_eq!(got, msg);
        assert_eq!(t, Some(ctx));
        let (got, t) =
            read_msg_traced(&mut cursor, &mut buf, &limits, true).unwrap().unwrap().unwrap();
        assert_eq!(got, hello);
        assert_eq!(t, None);
        let missing = read_msg_traced(&mut cursor, &mut buf, &limits, true).unwrap().unwrap();
        assert!(missing.is_err(), "traceless eligible frame on a traced session must not decode");
        assert!(read_msg_traced(&mut cursor, &mut buf, &limits, true).unwrap().is_none());

        // untraced sessions decode the plain stream as before — and reject
        // the traced frame (trailing bytes), which the size caps already
        // stopped earlier anyway
        let mut cursor = std::io::Cursor::new(&wire[frame.len()..]);
        let (got, t) =
            read_msg_traced(&mut cursor, &mut buf, &FrameLimits::permissive(), false)
                .unwrap()
                .unwrap()
                .unwrap();
        assert_eq!(got, hello);
        assert_eq!(t, None);
    }

    #[test]
    fn truncated_body_is_error_not_none() {
        let msg = Msg::Response(Response { client: 0, id: 0, action: vec![1.0] });
        let mut wire = msg.encode();
        wire.truncate(wire.len() - 2);
        let mut cursor = std::io::Cursor::new(wire);
        assert!(read_msg(&mut cursor).is_err());
    }

    #[test]
    fn tcp_loopback_roundtrip() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let msg = read_msg(&mut s).unwrap().unwrap();
            write_msg(&mut s, &msg).unwrap(); // echo
        });
        let mut c = std::net::TcpStream::connect(addr).unwrap();
        let msg = Msg::Request(Request {
            client: 5,
            id: 77,
            payload: Payload::RawRgba { x: 10, data: vec![3; 400] },
        });
        write_msg(&mut c, &msg).unwrap();
        assert_eq!(read_msg(&mut c).unwrap().unwrap(), msg);
        server.join().unwrap();
    }
}

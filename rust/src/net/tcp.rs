//! Length-prefixed message transport over any Read/Write pair (used with
//! loopback TCP in the serving experiments; composes with
//! [`super::shaped::ShapedWriter`] for bandwidth-shaped links).

use std::io::{Read, Write};

use anyhow::{ensure, Context, Result};

use super::framing::{Msg, MAX_FRAME};

/// Write one message (blocking).
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<()> {
    let frame = msg.encode();
    w.write_all(&frame).context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one message (blocking). Returns Ok(None) on clean EOF at a frame
/// boundary.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Option<Msg>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e).context("reading frame length"),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    ensure!(len > 0 && len <= MAX_FRAME, "bad frame length {len}");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("reading frame body")?;
    Ok(Some(Msg::decode(&body)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::framing::{Hello, Payload, Request, Response};

    #[test]
    fn roundtrip_over_a_buffer() {
        let msgs = vec![
            Msg::Hello(Hello { client: 1, split: true, shard: None }),
            Msg::Request(Request {
                client: 1,
                id: 1,
                payload: Payload::Features {
                    c: 4,
                    h: 11,
                    w: 11,
                    scale: 2.0,
                    data: vec![9; 484],
                },
            }),
            Msg::Response(Response { client: 1, id: 1, action: vec![0.25] }),
        ];
        let mut wire = Vec::new();
        for m in &msgs {
            write_msg(&mut wire, m).unwrap();
        }
        let mut cursor = std::io::Cursor::new(wire);
        for m in &msgs {
            assert_eq!(&read_msg(&mut cursor).unwrap().unwrap(), m);
        }
        assert!(read_msg(&mut cursor).unwrap().is_none()); // clean EOF
    }

    #[test]
    fn rejects_oversized_frame() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(u32::MAX).to_le_bytes());
        wire.push(1);
        let mut cursor = std::io::Cursor::new(wire);
        assert!(read_msg(&mut cursor).is_err());
    }

    #[test]
    fn truncated_body_is_error_not_none() {
        let msg = Msg::Response(Response { client: 0, id: 0, action: vec![1.0] });
        let mut wire = msg.encode();
        wire.truncate(wire.len() - 2);
        let mut cursor = std::io::Cursor::new(wire);
        assert!(read_msg(&mut cursor).is_err());
    }

    #[test]
    fn tcp_loopback_roundtrip() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let msg = read_msg(&mut s).unwrap().unwrap();
            write_msg(&mut s, &msg).unwrap(); // echo
        });
        let mut c = std::net::TcpStream::connect(addr).unwrap();
        let msg = Msg::Request(Request {
            client: 5,
            id: 77,
            payload: Payload::RawRgba { x: 10, data: vec![3; 400] },
        });
        write_msg(&mut c, &msg).unwrap();
        assert_eq!(read_msg(&mut c).unwrap().unwrap(), msg);
        server.join().unwrap();
    }
}

//! Resource budgets for the hostile edge of the wire (DESIGN.md §9).
//!
//! `net::framing` parses bytes that arrive from the open internet, so
//! every quantity a peer *claims* — frame lengths, element counts,
//! capability bits, codec ids — must be bounded here before it buys an
//! allocation or a state change. The module provides:
//!
//!   * [`LimitsConfig`] — the knobs: maximum observation/feature/action/
//!     parameter dimensions, the pre-Hello frame ceiling, per-connection
//!     malformed-frame and byte budgets, and the reader idle timeout;
//!   * [`FrameLimits`] — per-message-type frame-size caps, derived from
//!     the config. [`FrameLimits::pre_hello`] admits any legitimate
//!     opening frame but stays far below the blanket [`MAX_FRAME`];
//!     [`FrameLimits::negotiated`] tightens further once the Hello fixes
//!     the session's route (a split session has no business shipping
//!     4·X² raw observations, and vice versa);
//!   * [`SessionGate`] — the per-connection admission state machine:
//!     Hello negotiation (echo known codec ids, mask capability bits),
//!     pre-Hello byte metering, a malformed-frame budget, and a sticky
//!     `Quarantined` state. A quarantined session is disconnected
//!     without touching shard state or any other session;
//!   * [`RateCap`] — a time-agnostic token bucket (caller supplies the
//!     clock as `f64` seconds) shared by the threaded gateway and the
//!     deterministic simnet, unlike [`super::shaped::TokenBucket`] which
//!     paces *bytes* against the wall clock;
//!   * [`backoff_delay`] — the jittered exponential backoff clients use
//!     after an [`ERR_OVERLOADED`](super::framing) rejection.

use std::time::Duration;

use anyhow::{bail, ensure, Result};

use crate::util::rng::Rng;

use super::framing::{
    Hello, CAP_EXPERIENCE, CAP_TRACE, MAX_FRAME, MSG_ERROR, MSG_EXPERIENCE, MSG_HELLO, MSG_POLICY,
    MSG_REQUEST_FEAT, MSG_REQUEST_FEAT_V2, MSG_REQUEST_RAW, MSG_RESPONSE, MSG_RESPONSE_LEARN,
    MSG_RESPONSE_V2,
};
use crate::trace::{TRACE_ELIGIBLE, TRACE_WIRE_BYTES};

/// Resource-budget knobs for one listening endpoint. The defaults admit
/// everything the experiments and benches legitimately send while staying
/// an order of magnitude under the blanket 64 MiB [`MAX_FRAME`].
#[derive(Debug, Clone)]
pub struct LimitsConfig {
    /// largest observation edge (pixels) a raw-route request may claim
    /// (the frame body is 4·x² bytes)
    pub max_obs_x: u16,
    /// largest flattened feature map (c·h·w elements) a split-route
    /// request may claim
    pub max_feat_elems: usize,
    /// largest action vector a response frame may carry
    pub max_action_dim: usize,
    /// largest parameter vector a policy fan-out frame may carry
    pub max_policy_params: usize,
    /// largest error-frame detail string
    pub max_error_detail: usize,
    /// hard byte ceiling for any single frame before the Hello fixes the
    /// session's route (must still admit a legitimate opening request —
    /// raw-route sessions may open with a request instead of a Hello)
    pub pre_hello_frame: usize,
    /// undecodable frames a connection may send over its lifetime before
    /// it is quarantined (framing stays synchronized across a failed
    /// `Msg::decode`, so counting is exact). Healthy clients produce
    /// zero: TCP is checksummed, and codec chain breaks are handled one
    /// level up as need-keyframe feedback, not decode errors.
    pub max_decode_errors: u32,
    /// bytes a connection may send before completing its Hello (bounds a
    /// peer that streams request frames but never negotiates)
    pub max_pre_hello_bytes: u64,
    /// *consecutive* codec rejects (per client) before the session is
    /// quarantined. Consecutive, not absolute: a legitimate delta client
    /// takes one reject per chain break and recovers with the next
    /// keyframe, which resets the counter.
    pub max_codec_rejects: u32,
    /// reader-side idle timeout: a half-open client is reaped (and its
    /// session + codec state dropped) after this long without a frame
    pub idle_timeout: Duration,
}

impl Default for LimitsConfig {
    fn default() -> Self {
        LimitsConfig {
            max_obs_x: 1024,            // 4 MiB raw frame
            max_feat_elems: 1 << 20,    // 1 MiB flat feature map
            max_action_dim: 4096,
            max_policy_params: 1 << 22, // 16 MiB of f32 parameters
            max_error_detail: 4096,
            pre_hello_frame: 8 << 20,
            max_decode_errors: 8,
            max_pre_hello_bytes: 16 << 20,
            max_codec_rejects: 16,
            idle_timeout: Duration::from_secs(30),
        }
    }
}

impl LimitsConfig {
    // Frame-body sizes (type byte + payload — the `len` the transport
    // checks) for each message type at this config's maxima. Layouts
    // mirror `Msg::encode_into` exactly.
    fn hello_cap(&self) -> usize {
        // type + client + split + codec + caps + shard tag + shard id
        // + topology epoch (tag 2, the largest Hello layout)
        1 + 4 + 1 + 1 + 1 + 1 + 2 + 8
    }
    fn raw_cap(&self) -> usize {
        1 + 4 + 8 + 2 + 4 * self.max_obs_x as usize * self.max_obs_x as usize
    }
    fn feat_cap(&self) -> usize {
        1 + 4 + 8 + 6 + 4 + self.max_feat_elems
    }
    fn feat_v2_cap(&self) -> usize {
        1 + 4 + 8 + 6 + 3 + 4 + 4 + 4 + self.max_feat_elems
    }
    fn experience_cap(&self) -> usize {
        self.feat_v2_cap() + 13
    }
    fn response_cap(&self) -> usize {
        1 + 4 + 8 + 2 + 4 * self.max_action_dim
    }
    fn response_v2_cap(&self) -> usize {
        1 + 4 + 8 + 4 + 1 + 4 + 2 + 4 * self.max_action_dim
    }
    fn response_learn_cap(&self) -> usize {
        1 + 4 + 8 + 4 + 1 + 8 + 8 + 2 + 4 * self.max_action_dim
    }
    fn error_cap(&self) -> usize {
        1 + 4 + 1 + 2 + self.max_error_detail
    }
    fn policy_cap(&self) -> usize {
        1 + 8 + 4 + 4 * self.max_policy_params
    }
}

/// Per-message-type frame-size caps: the transport reads the type byte
/// first and checks the claimed length against `cap(ty)` *before*
/// allocating the body (`super::tcp::read_raw_frame_limited`). A type
/// with cap 0 (unknown ids, or a route the session did not negotiate) is
/// rejected outright.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrameLimits {
    /// indexed by message type id (0 unused; ids are 1..=10)
    caps: [usize; 11],
    hard_max: usize,
}

impl FrameLimits {
    /// Legacy behavior: every known type up to [`MAX_FRAME`]. Used where
    /// the peer is trusted (client reading its own server, loopback
    /// benches) and by the compatibility wrappers in `super::tcp`.
    pub fn permissive() -> Self {
        let mut caps = [MAX_FRAME; 11];
        caps[0] = 0;
        FrameLimits { caps, hard_max: MAX_FRAME }
    }

    /// Caps for a connection that has not completed its Hello: every
    /// type at its config-derived maximum, clamped to
    /// [`LimitsConfig::pre_hello_frame`].
    pub fn pre_hello(cfg: &LimitsConfig) -> Self {
        let mut l = Self::negotiated_union(cfg);
        for c in l.caps.iter_mut() {
            *c = (*c).min(cfg.pre_hello_frame);
        }
        l.hard_max = l.caps.iter().copied().max().unwrap_or(0);
        l
    }

    /// Caps once the Hello has fixed the session's route: the other
    /// route's request types collapse to 0 (a split session never ships
    /// raw observations; a raw session never ships feature frames).
    pub fn negotiated(split: bool, cfg: &LimitsConfig) -> Self {
        let mut l = Self::negotiated_union(cfg);
        if split {
            l.caps[MSG_REQUEST_RAW as usize] = 0;
        } else {
            l.caps[MSG_REQUEST_FEAT as usize] = 0;
            l.caps[MSG_REQUEST_FEAT_V2 as usize] = 0;
            l.caps[MSG_EXPERIENCE as usize] = 0;
        }
        l.hard_max = l.caps.iter().copied().max().unwrap_or(0);
        l
    }

    /// Both routes admitted at their config-derived maxima.
    fn negotiated_union(cfg: &LimitsConfig) -> Self {
        let mut caps = [0usize; 11];
        caps[MSG_HELLO as usize] = cfg.hello_cap();
        caps[MSG_REQUEST_RAW as usize] = cfg.raw_cap();
        caps[MSG_REQUEST_FEAT as usize] = cfg.feat_cap();
        caps[MSG_REQUEST_FEAT_V2 as usize] = cfg.feat_v2_cap();
        caps[MSG_EXPERIENCE as usize] = cfg.experience_cap();
        caps[MSG_RESPONSE as usize] = cfg.response_cap();
        caps[MSG_RESPONSE_V2 as usize] = cfg.response_v2_cap();
        caps[MSG_RESPONSE_LEARN as usize] = cfg.response_learn_cap();
        caps[MSG_ERROR as usize] = cfg.error_cap();
        caps[MSG_POLICY as usize] = cfg.policy_cap();
        let hard_max = caps.iter().copied().max().unwrap_or(0);
        FrameLimits { caps, hard_max }
    }

    /// Widen the caps for a session that negotiated
    /// [`CAP_TRACE`]: every *admitted* trace-eligible type
    /// (request payloads and response kinds — never Hello/Error/Policy)
    /// gains exactly [`TRACE_WIRE_BYTES`] for its trailer. Applied only
    /// after the Hello grants the capability, so a hostile pre-Hello
    /// length can never buy the allowance (DESIGN.md §12).
    pub fn allow_trace(&mut self) {
        for &ty in TRACE_ELIGIBLE.iter() {
            let c = &mut self.caps[ty as usize];
            if *c > 0 {
                *c += TRACE_WIRE_BYTES;
            }
        }
        self.hard_max = self.caps.iter().copied().max().unwrap_or(0);
    }

    /// Size cap for one message type (0 = not admitted at all).
    pub fn cap(&self, ty: u8) -> usize {
        self.caps.get(ty as usize).copied().unwrap_or(0)
    }

    /// Largest frame any admitted type may claim — checked before the
    /// type byte is even read.
    pub fn hard_max(&self) -> usize {
        self.hard_max
    }
}

/// Admission state of one connection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GateState {
    /// no Hello yet: tight caps, byte-metered
    PreHello,
    /// negotiated: the Hello fixed the route, codec, and capability set
    Ready { split: bool, codec: u8, caps: u8 },
    /// a budget was exhausted: nothing is admitted until disconnect
    Quarantined,
}

/// Per-connection admission state machine: Hello negotiation plus the
/// byte/decode-error budgets. Pure (no I/O, no clocks), so the fuzz
/// harness drives it directly and the threaded server and simnet share
/// the exact semantics.
#[derive(Debug, Clone)]
pub struct SessionGate {
    cfg: LimitsConfig,
    state: GateState,
    limits: FrameLimits,
    /// bytes admitted before the Hello completed
    pub pre_hello_bytes: u64,
    /// undecodable frames over the connection lifetime
    pub decode_errors: u32,
    /// the server's current topology epoch (0 = not fleet-fronted; epoch
    /// validation of client hellos is disabled and acks carry no epoch)
    topology_epoch: u64,
    /// highest epoch this session has presented and had accepted — the
    /// watermark a replayed pre-migration hello cannot regress below
    session_epoch: u64,
    /// hellos refused for a stale, regressed, or forged topology epoch
    /// (refused, not quarantined: a client racing a migration retries
    /// with the fresh epoch from its re-route ack)
    pub epoch_rejects: u32,
}

impl SessionGate {
    pub fn new(cfg: LimitsConfig) -> Self {
        let limits = FrameLimits::pre_hello(&cfg);
        SessionGate {
            cfg,
            state: GateState::PreHello,
            limits,
            pre_hello_bytes: 0,
            decode_errors: 0,
            topology_epoch: 0,
            session_epoch: 0,
            epoch_rejects: 0,
        }
    }

    /// Adopt the fleet's current topology epoch (bumped on every shard
    /// add/remove/state change). Once nonzero, epoch-carrying hellos are
    /// validated against it and acks stamp it back to the client.
    pub fn set_topology_epoch(&mut self, epoch: u64) {
        self.topology_epoch = epoch;
    }

    /// The gate a migrated session starts with on its new shard
    /// (DESIGN.md §10): budgets and negotiation state reset — the new
    /// shard saw none of the old shard's frames, so the old shard's
    /// decode-error budget must not follow the session — but the epoch
    /// watermarks carry, so a replayed pre-migration hello cannot
    /// re-route the session backwards.
    pub fn migrate(&self) -> SessionGate {
        let mut g = SessionGate::new(self.cfg.clone());
        g.topology_epoch = self.topology_epoch;
        g.session_epoch = self.session_epoch;
        g
    }

    pub fn state(&self) -> &GateState {
        &self.state
    }

    /// The frame-size caps the transport must currently enforce.
    pub fn limits(&self) -> &FrameLimits {
        &self.limits
    }

    pub fn quarantined(&self) -> bool {
        self.state == GateState::Quarantined
    }

    /// Negotiate (or re-negotiate — a repeated Hello resets the codec
    /// chain, mirroring the executor's `Decoders::invalidate`) and return
    /// the ack to send: the codec id is echoed only if the server knows
    /// it (unknown ids decline to flat), and the capability bits are
    /// masked down to `caps_mask`. A quarantined session gets no ack.
    ///
    /// A hello carrying a topology epoch is validated first (DESIGN.md
    /// §10): an epoch behind the server's, ahead of the server's (a
    /// forged mid-migration re-route), or behind the session's own
    /// watermark is refused — no ack, no state change, no quarantine.
    pub fn on_hello(&mut self, h: &Hello, caps_mask: u8, shard: Option<u16>) -> Option<Hello> {
        if self.quarantined() {
            return None;
        }
        if let Some(e) = h.epoch {
            let stale_or_forged = self.topology_epoch > 0 && e != self.topology_epoch;
            if stale_or_forged || e < self.session_epoch {
                self.epoch_rejects = self.epoch_rejects.saturating_add(1);
                return None;
            }
            self.session_epoch = e;
        }
        let codec = if crate::codec::CodecId::from_wire(h.codec).is_some() { h.codec } else { 0 };
        let caps = h.caps & caps_mask;
        self.state = GateState::Ready { split: h.split, codec, caps };
        self.limits = FrameLimits::negotiated(h.split, &self.cfg);
        if caps & CAP_TRACE != 0 {
            // the session's frames now carry the fixed trace trailer; the
            // allowance is exact, per type, and only post-negotiation
            self.limits.allow_trace();
        }
        let epoch = (self.topology_epoch > 0).then_some(self.topology_epoch);
        Some(Hello { client: h.client, split: h.split, codec, caps, shard, epoch })
    }

    /// True if the negotiated capability set includes `cap` (always false
    /// before the Hello and under quarantine).
    pub fn grants(&self, cap: u8) -> bool {
        matches!(self.state, GateState::Ready { caps, .. } if caps & cap != 0)
    }

    /// Gate one frame of `len` body bytes of type `ty` before it is
    /// decoded. Checks quarantine, the per-type cap, the experience
    /// capability, and (pre-Hello) the byte budget — a budget violation
    /// quarantines the session.
    pub fn admit(&mut self, ty: u8, len: usize) -> Result<()> {
        ensure!(!self.quarantined(), "session is quarantined");
        let cap = self.limits.cap(ty);
        ensure!(cap > 0, "frame type {ty} not admitted on this session");
        ensure!(len <= cap, "frame type {ty} length {len} exceeds cap {cap}");
        if ty == MSG_EXPERIENCE && !self.grants(CAP_EXPERIENCE) {
            // not a quarantine offense: the server answers with an
            // explicit ErrorMsg and the client downgrades (DESIGN.md §8)
            bail!("experience frame without the negotiated CAP_EXPERIENCE");
        }
        if self.state == GateState::PreHello {
            self.pre_hello_bytes += len as u64;
            if self.pre_hello_bytes > self.cfg.max_pre_hello_bytes {
                self.state = GateState::Quarantined;
                bail!("pre-hello byte budget exhausted");
            }
        }
        Ok(())
    }

    /// Count one undecodable frame. Returns true when the budget is
    /// exhausted — the session is now quarantined and must be
    /// disconnected (without touching any other session's state).
    pub fn on_decode_error(&mut self) -> bool {
        self.decode_errors = self.decode_errors.saturating_add(1);
        if self.decode_errors > self.cfg.max_decode_errors {
            self.state = GateState::Quarantined;
            true
        } else {
            false
        }
    }
}

/// Per-client request-rate cap: a token bucket over an externally
/// supplied clock (`f64` seconds), so the threaded gateway feeds it wall
/// time and the deterministic simnet feeds it virtual time.
#[derive(Debug, Clone)]
pub struct RateCap {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: f64,
}

impl RateCap {
    /// `rate_hz` requests per second sustained, `burst` admitted at once.
    pub fn new(rate_hz: f64, burst: f64) -> Self {
        RateCap { rate: rate_hz.max(0.0), burst: burst.max(1.0), tokens: burst.max(1.0), last: 0.0 }
    }

    /// Admit one request at time `now` (seconds, monotone per caller).
    pub fn allow(&mut self, now: f64) -> bool {
        let dt = (now - self.last).max(0.0);
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Jittered exponential backoff (seconds) for the `attempt`-th retry
/// after an overload rejection: `base·2^attempt` capped at `cap`, with
/// full jitter in `[d/2, d)` so a shed flash crowd does not re-arrive in
/// lockstep.
///
/// Total over degenerate inputs: any `attempt` saturates at `cap` (the
/// exponential is computed in `f64` and overflow collapses to the cap),
/// and inf/NaN/negative `base` or `cap` still yield a finite non-negative
/// delay — retry schedulers sleep on this value, so it must never be
/// inf or NaN. The jitter stream advances exactly once per call on every
/// path, keeping seeded replay byte-stable.
pub fn backoff_delay(base: f64, attempt: u32, cap: f64, rng: &mut Rng) -> f64 {
    let cap = if cap.is_finite() { cap.max(0.0) } else { f64::MAX };
    let exp = base.max(0.0) * 2f64.powi(attempt.min(1024) as i32);
    let d = if exp.is_finite() { exp.min(cap) } else { cap };
    d * (0.5 + 0.5 * rng.uniform())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::framing::{
        ErrorMsg, FeatureFrame, Msg, Payload, PolicySync, Request, Response,
    };

    #[test]
    fn derived_caps_admit_maximal_legitimate_frames() {
        let cfg = LimitsConfig { max_obs_x: 8, max_feat_elems: 12, max_action_dim: 3, ..LimitsConfig::default() };
        let l = FrameLimits::pre_hello(&cfg);
        let cases = [
            Msg::Hello(Hello { client: 1, split: true, codec: 1, caps: 1, shard: Some(3), epoch: None }),
            Msg::Request(Request {
                client: 1,
                id: 1,
                payload: Payload::RawRgba { x: 8, data: vec![0; 4 * 64] },
            }),
            Msg::Request(Request {
                client: 1,
                id: 2,
                payload: Payload::Features { c: 3, h: 2, w: 2, scale: 1.0, data: vec![0; 12] },
            }),
            Msg::Request(Request {
                client: 1,
                id: 3,
                payload: Payload::FeaturesV2(FeatureFrame {
                    c: 3,
                    h: 2,
                    w: 2,
                    codec: 1,
                    flags: 2,
                    qmax: 255,
                    seq: 0,
                    scale: 1.0,
                    data: vec![0; 12],
                }),
            }),
            Msg::Response(Response { client: 1, id: 1, action: vec![0.0; 3] }),
            Msg::Error(ErrorMsg { client: 1, code: 1, detail: "x".into() }),
        ];
        for m in cases {
            let enc = m.encode();
            let body = &enc[4..];
            assert!(
                body.len() <= l.cap(body[0]),
                "cap {} too small for {} bytes of type {}",
                l.cap(body[0]),
                body.len(),
                body[0]
            );
        }
    }

    #[test]
    fn pre_hello_caps_stay_far_below_max_frame_and_unknown_types_get_zero() {
        let l = FrameLimits::pre_hello(&LimitsConfig::default());
        for ty in 1..=10u8 {
            assert!(l.cap(ty) > 0, "type {ty} must stay admitted pre-hello");
            assert!(l.cap(ty) <= 8 << 20, "type {ty} cap escapes the pre-hello ceiling");
        }
        assert_eq!(l.cap(0), 0);
        assert_eq!(l.cap(11), 0);
        assert_eq!(l.cap(255), 0);
        assert!(l.hard_max() <= 8 << 20);
        assert!(l.hard_max() < MAX_FRAME);
    }

    #[test]
    fn negotiation_collapses_the_other_route() {
        let cfg = LimitsConfig::default();
        let split = FrameLimits::negotiated(true, &cfg);
        assert_eq!(split.cap(MSG_REQUEST_RAW), 0);
        assert!(split.cap(MSG_REQUEST_FEAT_V2) > 0);
        assert!(split.cap(MSG_EXPERIENCE) > 0);
        let raw = FrameLimits::negotiated(false, &cfg);
        assert!(raw.cap(MSG_REQUEST_RAW) > 0);
        assert_eq!(raw.cap(MSG_REQUEST_FEAT), 0);
        assert_eq!(raw.cap(MSG_REQUEST_FEAT_V2), 0);
        assert_eq!(raw.cap(MSG_EXPERIENCE), 0);
    }

    #[test]
    fn policy_cap_bounds_the_biggest_admitted_frame() {
        let cfg = LimitsConfig::default();
        let l = FrameLimits::negotiated(true, &cfg);
        let pol = Msg::Policy(PolicySync { version: 1, params: vec![0.0; 16] }).encode();
        assert!(pol.len() - 4 <= l.cap(MSG_POLICY));
        assert_eq!(l.hard_max(), l.cap(MSG_POLICY).max(l.cap(MSG_EXPERIENCE)));
    }

    #[test]
    fn gate_negotiation_echoes_known_codecs_and_masks_caps() {
        let mut g = SessionGate::new(LimitsConfig::default());
        assert_eq!(*g.state(), GateState::PreHello);
        let h = Hello { client: 9, split: true, codec: 1, caps: CAP_EXPERIENCE, shard: None, epoch: None };
        let ack = g.on_hello(&h, CAP_EXPERIENCE, Some(2)).unwrap();
        assert_eq!(ack.codec, 1);
        assert_eq!(ack.caps, CAP_EXPERIENCE);
        assert_eq!(ack.shard, Some(2));
        assert!(g.grants(CAP_EXPERIENCE));

        // unknown codec id declines to flat; a zero mask clears the caps
        let mut g = SessionGate::new(LimitsConfig::default());
        let h = Hello { client: 9, split: true, codec: 77, caps: CAP_EXPERIENCE, shard: None, epoch: None };
        let ack = g.on_hello(&h, 0, None).unwrap();
        assert_eq!(ack.codec, 0);
        assert_eq!(ack.caps, 0);
        assert!(!g.grants(CAP_EXPERIENCE));
    }

    #[test]
    fn gate_renegotiation_flips_routes_and_capability_bits() {
        let cfg = LimitsConfig::default();
        let mut g = SessionGate::new(cfg.clone());
        g.on_hello(
            &Hello { client: 1, split: true, codec: 1, caps: CAP_EXPERIENCE, shard: None, epoch: None },
            CAP_EXPERIENCE,
            None,
        )
        .unwrap();
        assert!(g.admit(MSG_EXPERIENCE, 64).is_ok());
        assert!(g.admit(MSG_REQUEST_RAW, 64).is_err(), "split session must not ship raw frames");
        // a mid-session capability flip takes effect immediately
        g.on_hello(
            &Hello { client: 1, split: true, codec: 1, caps: 0, shard: None, epoch: None },
            CAP_EXPERIENCE,
            None,
        )
        .unwrap();
        assert!(g.admit(MSG_EXPERIENCE, 64).is_err(), "flipped-off capability must not admit");
        assert!(g.admit(MSG_REQUEST_FEAT_V2, 64).is_ok());
    }

    #[test]
    fn gate_admits_within_caps_and_rejects_oversize_without_quarantining() {
        let mut g = SessionGate::new(LimitsConfig::default());
        assert!(g.admit(MSG_HELLO, 11).is_ok());
        assert!(g.admit(MSG_HELLO, 4096).is_err());
        assert!(!g.quarantined(), "an oversize claim alone is rejected, not quarantined");
        assert!(g.admit(99, 1).is_err(), "unknown type");
    }

    #[test]
    fn pre_hello_byte_budget_quarantines() {
        let cfg = LimitsConfig { max_pre_hello_bytes: 100, ..LimitsConfig::default() };
        let mut g = SessionGate::new(cfg);
        assert!(g.admit(MSG_REQUEST_RAW, 60).is_ok());
        assert!(g.admit(MSG_REQUEST_RAW, 60).is_err(), "budget exhausted");
        assert!(g.quarantined());
        // quarantine is sticky: no frames, no hello, no ack
        assert!(g.admit(MSG_HELLO, 11).is_err());
        assert!(g
            .on_hello(&Hello { client: 1, split: false, codec: 0, caps: 0, shard: None, epoch: None }, 0, None)
            .is_none());
    }

    #[test]
    fn decode_error_budget_quarantines_at_threshold() {
        let cfg = LimitsConfig { max_decode_errors: 3, ..LimitsConfig::default() };
        let mut g = SessionGate::new(cfg);
        assert!(!g.on_decode_error());
        assert!(!g.on_decode_error());
        assert!(!g.on_decode_error());
        assert!(g.on_decode_error(), "fourth malformed frame exceeds a budget of 3");
        assert!(g.quarantined());
        assert!(g.admit(MSG_HELLO, 11).is_err());
    }

    #[test]
    fn epoch_carrying_hellos_validate_against_the_topology_epoch() {
        let mut g = SessionGate::new(LimitsConfig::default());
        g.set_topology_epoch(5);
        let hello = |e: Option<u64>| Hello {
            client: 1,
            split: true,
            codec: 1,
            caps: 0,
            shard: None,
            epoch: e,
        };
        // matching epoch negotiates and the ack stamps the server's epoch
        let ack = g.on_hello(&hello(Some(5)), 0, Some(2)).expect("current epoch must ack");
        assert_eq!(ack.epoch, Some(5));
        assert_eq!(ack.shard, Some(2));
        // a stale epoch (behind the topology) is refused without quarantine
        assert!(g.on_hello(&hello(Some(4)), 0, None).is_none());
        assert_eq!(g.epoch_rejects, 1);
        assert!(!g.quarantined(), "epoch refusal must not quarantine");
        // a forged future epoch (mid-migration re-route) is refused too
        assert!(g.on_hello(&hello(Some(9)), 0, None).is_none());
        assert_eq!(g.epoch_rejects, 2);
        // an epoch-less hello still negotiates (legacy clients) and the
        // ack carries the fleet epoch forward
        let ack = g.on_hello(&hello(None), 0, None).expect("legacy hello must ack");
        assert_eq!(ack.epoch, Some(5));
    }

    #[test]
    fn session_epoch_watermark_refuses_regression_even_without_a_fleet() {
        // topology_epoch 0 (shard-direct server): stale/forged checks are
        // off, but a session that presented epoch 7 can never present a
        // smaller one — a replayed pre-migration hello must not re-route
        // the session backwards
        let mut g = SessionGate::new(LimitsConfig::default());
        let hello = |e: u64| Hello {
            client: 1,
            split: false,
            codec: 0,
            caps: 0,
            shard: None,
            epoch: Some(e),
        };
        assert!(g.on_hello(&hello(7), 0, None).is_some());
        // the ack carries no epoch when the server is not fleet-fronted
        assert_eq!(g.on_hello(&hello(7), 0, None).unwrap().epoch, None);
        assert!(g.on_hello(&hello(3), 0, None).is_none(), "regressed epoch accepted");
        assert_eq!(g.epoch_rejects, 1);
        assert!(!g.quarantined());
        assert!(g.on_hello(&hello(8), 0, None).is_some(), "advancing epoch must recover");
    }

    #[test]
    fn migrated_gate_resets_budgets_but_keeps_the_epoch_watermark() {
        // the satellite-2 regression: decode-error budgets must NOT follow
        // a session across a migration — the new shard saw none of the old
        // shard's frames
        let cfg = LimitsConfig { max_decode_errors: 3, ..LimitsConfig::default() };
        let mut g = SessionGate::new(cfg);
        g.set_topology_epoch(2);
        assert!(g
            .on_hello(
                &Hello {
                    client: 4,
                    split: true,
                    codec: 1,
                    caps: 0,
                    shard: None,
                    epoch: Some(2)
                },
                0,
                Some(0),
            )
            .is_some());
        for _ in 0..3 {
            assert!(!g.on_decode_error());
        }
        assert_eq!(g.decode_errors, 3, "one error away from quarantine");

        // migrate: fresh budgets, fresh negotiation state...
        let mut m = g.migrate();
        assert_eq!(m.decode_errors, 0, "decode-error budget carried over the migration");
        assert_eq!(m.pre_hello_bytes, 0);
        assert_eq!(*m.state(), GateState::PreHello, "the new shard renegotiates from scratch");
        assert!(!m.on_decode_error(), "a fresh budget must absorb a chain-break error");
        // ...but the epoch watermark survives: the old shard's accepted
        // epoch still bounds what the session may present
        assert!(
            m.on_hello(
                &Hello {
                    client: 4,
                    split: true,
                    codec: 1,
                    caps: 0,
                    shard: None,
                    epoch: Some(1)
                },
                0,
                Some(1),
            )
            .is_none(),
            "pre-migration epoch replay accepted on the new shard"
        );
        assert_eq!(m.epoch_rejects, 1);
        // and a quarantined gate migrates into a *serving* gate — the
        // quarantine was the old shard's verdict on the old budget
        assert!(g.on_decode_error());
        assert!(g.quarantined());
        let m2 = g.migrate();
        assert!(!m2.quarantined());
    }

    #[test]
    fn allow_trace_widens_only_admitted_eligible_types_by_the_trailer() {
        let cfg = LimitsConfig::default();
        let base = FrameLimits::negotiated(true, &cfg);
        let mut traced = base.clone();
        traced.allow_trace();
        for ty in 0..=11u8 {
            let (b, t) = (base.cap(ty), traced.cap(ty));
            if TRACE_ELIGIBLE.contains(&ty) && b > 0 {
                assert_eq!(t, b + TRACE_WIRE_BYTES, "type {ty} must gain exactly the trailer");
            } else {
                assert_eq!(t, b, "type {ty} must not gain a trace allowance");
            }
        }
        // the collapsed route stays collapsed: no trailer resurrects raw
        assert_eq!(traced.cap(MSG_REQUEST_RAW), 0);
        // control traffic never widens
        assert_eq!(traced.cap(MSG_HELLO), base.cap(MSG_HELLO));
        assert_eq!(traced.cap(MSG_ERROR), base.cap(MSG_ERROR));
        assert_eq!(traced.cap(MSG_POLICY), base.cap(MSG_POLICY));
        assert_eq!(traced.hard_max(), base.hard_max().max(traced.cap(MSG_EXPERIENCE)));
    }

    #[test]
    fn gate_grants_trace_allowance_only_after_the_hello_grants_the_cap() {
        use crate::net::framing::CAP_TRACE;
        let cfg = LimitsConfig::default();
        let feat_cap = FrameLimits::negotiated(true, &cfg).cap(MSG_REQUEST_FEAT_V2);
        let hello = |caps: u8| Hello { client: 1, split: true, codec: 1, caps, shard: None, epoch: None };

        // granted: eligible frames get exactly the trailer allowance
        let mut g = SessionGate::new(cfg.clone());
        let ack = g.on_hello(&hello(CAP_TRACE), CAP_TRACE, None).unwrap();
        assert_eq!(ack.caps, CAP_TRACE);
        assert!(g.grants(CAP_TRACE));
        assert!(g.admit(MSG_REQUEST_FEAT_V2, feat_cap + TRACE_WIRE_BYTES).is_ok());
        assert!(g.admit(MSG_REQUEST_FEAT_V2, feat_cap + TRACE_WIRE_BYTES + 1).is_err());
        assert!(g.admit(MSG_HELLO, cfg.hello_cap() + TRACE_WIRE_BYTES).is_err(), "hello never widens");

        // requested but masked off: no allowance
        let mut g = SessionGate::new(cfg.clone());
        let ack = g.on_hello(&hello(CAP_TRACE), 0, None).unwrap();
        assert_eq!(ack.caps, 0);
        assert!(!g.grants(CAP_TRACE));
        assert!(g.admit(MSG_REQUEST_FEAT_V2, feat_cap + TRACE_WIRE_BYTES).is_err());

        // never requested: no allowance either, and pre-hello is untouched
        let g = SessionGate::new(cfg);
        assert_eq!(g.limits().cap(MSG_REQUEST_FEAT_V2), feat_cap.min(g.limits().hard_max()));
    }

    #[test]
    fn rate_cap_denies_past_burst_and_refills_with_time() {
        let mut r = RateCap::new(10.0, 2.0);
        assert!(r.allow(0.0));
        assert!(r.allow(0.0));
        assert!(!r.allow(0.0), "burst of 2 exhausted");
        assert!(r.allow(0.1), "0.1 s at 10 Hz refills one token");
        assert!(!r.allow(0.1));
        // time never flows backwards into extra tokens
        assert!(!r.allow(0.05));
    }

    #[test]
    fn backoff_is_jittered_bounded_and_grows() {
        let mut rng = Rng::new(7);
        let mut prev_cap = 0.0f64;
        for attempt in 0..10 {
            let d = backoff_delay(0.01, attempt, 1.0, &mut rng);
            let full = (0.01 * (1u64 << attempt.min(16)) as f64).min(1.0);
            assert!(d >= full * 0.5 && d < full, "attempt {attempt}: {d} outside [{}, {full})", full * 0.5);
            assert!(full >= prev_cap, "envelope must be monotone");
            prev_cap = full;
        }
        // huge attempt counts must not overflow the shift
        let d = backoff_delay(0.01, u32::MAX, 1.0, &mut rng);
        assert!(d <= 1.0);
    }

    /// Property (ISSUE 9 satellite): `backoff_delay` is total — finite,
    /// non-negative, and at most `cap` for every attempt count, including
    /// ones whose exponential overflows `f64`.
    #[test]
    fn backoff_delay_saturates_at_cap_and_stays_finite() {
        use crate::util::proptest::{check, prop_assert};
        check(300, |g| {
            let base = g.f64(1e-6, 10.0);
            let cap = g.f64(1e-3, 60.0);
            let attempt = match g.usize(0, 3) {
                0 => g.u64(0, 20) as u32,
                1 => g.u64(21, 2_000) as u32,
                2 => u32::MAX,
                _ => 0,
            };
            let d = backoff_delay(base, attempt, cap, g.rng());
            prop_assert(
                d.is_finite() && d >= 0.0,
                format!("backoff({base}, {attempt}, {cap}) = {d}"),
            )?;
            prop_assert(d <= cap, format!("delay {d} above cap {cap}"))?;
            Ok(())
        });
        // degenerate scalars must still come back finite and non-negative
        let mut rng = Rng::new(7);
        for (base, cap) in [
            (f64::NAN, 1.0),
            (1.0, f64::NAN),
            (f64::INFINITY, 1.0),
            (1.0, f64::INFINITY),
            (-1.0, 1.0),
            (1.0, -1.0),
        ] {
            let d = backoff_delay(base, u32::MAX, cap, &mut rng);
            assert!(d.is_finite() && d >= 0.0, "backoff({base}, u32::MAX, {cap}) = {d}");
        }
    }
}

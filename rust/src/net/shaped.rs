//! Bandwidth shaping: a token-bucket pacer that makes a real byte stream
//! behave like a B-bits-per-second link (the `tc netem`-style shaping the
//! paper applies in §4.3), plus an analytic link model used by the
//! deterministic experiments.
//!
//! All time flows through the [`crate::sim::Clock`] seam: production code
//! pays real sleeps ([`ShapedWriter::new`] uses the wall clock), while
//! tests and the simnet drive the identical refill/deficit arithmetic
//! under a virtual clock with zero real waiting
//! ([`ShapedWriter::with_clock`]).

use std::io::{Read, Write};
use std::time::{Duration, Instant};

use crate::sim::clock::ClockHandle;

/// Token bucket over an injected clock's instants. `rate_bps` is in *bits*
/// per second (matching the paper's Mb/s figures); burst is the bucket
/// depth in bytes.
#[derive(Debug)]
pub struct TokenBucket {
    rate_bytes_per_sec: f64,
    burst_bytes: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(rate_bps: f64, burst_bytes: usize) -> TokenBucket {
        Self::new_at(rate_bps, burst_bytes, Instant::now())
    }

    /// Construct against an explicit epoch — required under a sim clock,
    /// where `Instant::now()` would smuggle a wall-clock read (and a
    /// nondeterministic first refill) into virtual time.
    pub fn new_at(rate_bps: f64, burst_bytes: usize, now: Instant) -> TokenBucket {
        assert!(
            rate_bps > 0.0 && rate_bps.is_finite(),
            "token bucket needs a positive finite rate (got {rate_bps})"
        );
        TokenBucket {
            rate_bytes_per_sec: rate_bps / 8.0,
            burst_bytes: (burst_bytes as f64).max(1.0),
            tokens: burst_bytes as f64,
            last: now,
        }
    }

    /// Bucket depth in bytes.
    pub fn burst_bytes(&self) -> usize {
        self.burst_bytes as usize
    }

    fn refill(&mut self, now: Instant) {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * self.rate_bytes_per_sec).min(self.burst_bytes);
        self.last = now;
    }

    /// How long to wait before `n` bytes may be sent (0 if sendable now).
    ///
    /// A demand larger than the bucket depth can never be met by waiting —
    /// refill caps at `burst_bytes`, so the naive deficit would starve the
    /// caller forever. The demand is clamped to the depth instead: the
    /// caller is released once the bucket is full, and its `consume`
    /// drives the balance negative, back-pressuring subsequent sends by
    /// exactly the overshoot. The returned delay is always finite and
    /// non-negative.
    pub fn delay_for(&mut self, n: usize, now: Instant) -> Duration {
        self.refill(now);
        let need = (n as f64).min(self.burst_bytes);
        if self.tokens >= need {
            Duration::ZERO
        } else {
            let deficit = need - self.tokens;
            Duration::from_secs_f64(deficit / self.rate_bytes_per_sec)
        }
    }

    /// Consume `n` bytes' worth of tokens (may go negative => back-pressure).
    pub fn consume(&mut self, n: usize) {
        self.tokens -= n as f64;
    }
}

/// A writer that paces bytes through a token bucket (sleeping as needed),
/// then forwards to the inner writer. Chunks large writes so pacing is
/// smooth rather than bursty.
pub struct ShapedWriter<W: Write> {
    inner: W,
    bucket: TokenBucket,
    chunk: usize,
    clock: ClockHandle,
}

impl<W: Write> ShapedWriter<W> {
    pub fn new(inner: W, rate_bps: f64) -> ShapedWriter<W> {
        Self::with_clock(inner, rate_bps, ClockHandle::wall())
    }

    /// Pace against an injected clock: under a `SimClock`, the delay loop
    /// advances virtual time instead of sleeping — the shaped-link
    /// property tests run arbitrary write schedules in microseconds.
    pub fn with_clock(inner: W, rate_bps: f64, clock: ClockHandle) -> ShapedWriter<W> {
        // bucket depth ~ 20ms of the link rate: small enough for smooth
        // pacing, big enough to not throttle tiny frames artificially
        let burst = ((rate_bps / 8.0) * 0.02).max(1500.0) as usize;
        let bucket = TokenBucket::new_at(rate_bps, burst, clock.now());
        ShapedWriter { inner, bucket, chunk: 1500, clock }
    }

    pub fn into_inner(self) -> W {
        self.inner
    }
}

impl<W: Write> Write for ShapedWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = buf.len().min(self.chunk);
        loop {
            let d = self.bucket.delay_for(n, self.clock.now());
            if d.is_zero() {
                break;
            }
            self.clock.sleep(d);
        }
        self.bucket.consume(n);
        self.inner.write_all(&buf[..n])?;
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// Pass-through reader (reads are paced by the sender's shaping).
pub struct PlainReader<R: Read>(pub R);

impl<R: Read> Read for PlainReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.0.read(buf)
    }
}

/// Analytic link model: serialisation + propagation delay for `bytes` over
/// a `rate_bps` link with one-way `latency` — the deterministic counterpart
/// used by the break-even analysis and the sim-mode experiments.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    pub rate_bps: f64,
    pub one_way_latency: f64,
}

impl LinkModel {
    pub fn new(rate_bps: f64, one_way_latency: f64) -> LinkModel {
        LinkModel { rate_bps, one_way_latency }
    }

    /// Time for `bytes` to fully arrive at the receiver.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.one_way_latency + (bytes * 8) as f64 / self.rate_bps
    }

    /// Full request/response decision-loop network time: request bytes up,
    /// response bytes down.
    pub fn round_trip(&self, up_bytes: usize, down_bytes: usize) -> f64 {
        self.transfer_time(up_bytes) + self.transfer_time(down_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_model_arithmetic() {
        // 1 MB at 10 Mb/s = 0.8 s (+ latency)
        let l = LinkModel::new(10e6, 0.005);
        let t = l.transfer_time(1_000_000);
        assert!((t - 0.805).abs() < 1e-9, "{t}");
        let rt = l.round_trip(1_000_000, 100);
        assert!((rt - (0.805 + 0.005 + 800.0 / 10e6)).abs() < 1e-9);
    }

    #[test]
    fn paper_anchor_raw_frame_at_10mbps() {
        // X=400 RGBA = 640 kB = 5.12 Mb -> 512 ms at 10 Mb/s: the dominant
        // term in the paper's 540 ms server-only latency
        let l = LinkModel::new(10e6, 0.0);
        let t = l.transfer_time(4 * 400 * 400);
        assert!((t - 0.512).abs() < 1e-6, "{t}");
    }

    #[test]
    fn bucket_delays_when_empty() {
        let mut b = TokenBucket::new(8000.0, 100); // 1000 B/s, 100 B burst
        let t0 = Instant::now();
        assert_eq!(b.delay_for(100, t0), Duration::ZERO);
        b.consume(100);
        let d = b.delay_for(100, t0);
        // need 100 bytes at 1000 B/s = 100 ms
        assert!((d.as_secs_f64() - 0.1).abs() < 0.01, "{d:?}");
    }

    #[test]
    fn bucket_refills_over_time() {
        let mut b = TokenBucket::new(8000.0, 1000);
        let t0 = Instant::now();
        b.refill(t0);
        b.consume(1000);
        let later = t0 + Duration::from_millis(500); // +500 B
        let d = b.delay_for(400, later);
        assert_eq!(d, Duration::ZERO);
        let d2 = b.delay_for(600, later);
        assert!(d2 > Duration::ZERO);
    }

    #[test]
    fn shaped_writer_achieves_target_rate() {
        // 800 kb/s = 100 kB/s; sending 30 kB should take ~0.3s (minus burst)
        let buf: Vec<u8> = vec![0; 30_000];
        let mut w = ShapedWriter::new(Vec::new(), 800_000.0);
        let t0 = Instant::now();
        w.write_all(&buf).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        // burst gives ~2 kB head start; expect 0.25..0.40 s
        assert!((0.2..0.45).contains(&dt), "took {dt}s");
        assert_eq!(w.into_inner().len(), 30_000);
    }

    #[test]
    fn shaped_writer_fast_link_is_fast() {
        let buf = vec![0u8; 30_000];
        let mut w = ShapedWriter::new(Vec::new(), 1e9);
        let t0 = Instant::now();
        w.write_all(&buf).unwrap();
        assert!(t0.elapsed().as_secs_f64() < 0.05);
    }

    #[test]
    fn oversized_demand_does_not_starve() {
        // Regression: a demand above the bucket depth used to make
        // `delay_for` unsatisfiable forever (refill caps at burst), so a
        // delay/sleep/retry loop spun without end. Now the demand clamps
        // to the depth: wait once, send, go negative.
        let mut b = TokenBucket::new(8_000.0, 100); // 1000 B/s, 100 B deep
        let t0 = Instant::now();
        b.consume(100); // empty it
        let d = b.delay_for(500, t0);
        assert!(d > Duration::ZERO);
        // a full refill satisfies the clamped demand
        let later = t0 + d;
        assert_eq!(b.delay_for(500, later), Duration::ZERO);
        b.consume(500); // -400: the overshoot back-pressures the next send
        let d2 = b.delay_for(100, later);
        assert!((d2.as_secs_f64() - 0.5).abs() < 0.01, "{d2:?}");
    }

    #[test]
    fn shaped_writer_virtual_clock_paces_without_real_sleeps() {
        use crate::sim::clock::SimClock;
        // 800 kb/s = 100 kB/s: 50 kB takes ~0.5 s of *virtual* time
        let clock = SimClock::new();
        let mut w = ShapedWriter::with_clock(Vec::new(), 800_000.0, clock.handle());
        let real0 = Instant::now();
        w.write_all(&[7u8; 50_000]).unwrap();
        assert!(real0.elapsed().as_secs_f64() < 0.2, "slept in real time");
        let vt = clock.now_secs();
        // burst gives a 2 kB head start: expect ~0.48 s of virtual pacing
        assert!((0.4..0.6).contains(&vt), "virtual time {vt}");
        assert_eq!(w.into_inner().len(), 50_000);
    }

    #[test]
    fn virtual_and_wall_bucket_arithmetic_agree() {
        // same instants, same answers: the clock seam changes the source
        // of instants, never the arithmetic
        let t0 = Instant::now();
        let mut a = TokenBucket::new_at(1e6, 2500, t0);
        let mut b = TokenBucket::new_at(1e6, 2500, t0);
        for i in 0..200u64 {
            let now = t0 + Duration::from_millis(i * 3);
            let d1 = a.delay_for(1500, now);
            let d2 = b.delay_for(1500, now);
            assert_eq!(d1, d2);
            a.consume(1500);
            b.consume(1500);
        }
    }
}

//! Network stack: wire [`framing`] for the split-policy protocol (uint8
//! observation/feature buffers, per the paper §4.2), bandwidth [`shaped`]
//! links (token-bucket pacing over real sockets + analytic model), and the
//! length-prefixed [`tcp`] transport.

pub mod framing;
pub mod shaped;
pub mod tcp;

pub use framing::{
    dequantize_features, dequantize_features_into, encode_response_into,
    encode_response_v2_into, quantize_features, quantize_features_into, FeatureFrame, Hello, Msg,
    Payload, Request, Response, ResponseV2, RESP_FLAG_NEED_KEYFRAME,
};
pub use shaped::{LinkModel, ShapedWriter, TokenBucket};
pub use tcp::{read_msg, read_raw_frame, write_frame, write_msg, write_raw_frame};

//! Network stack: wire [`framing`] for the split-policy protocol (uint8
//! observation/feature buffers, per the paper §4.2), bandwidth [`shaped`]
//! links (token-bucket pacing over real sockets + analytic model), the
//! length-prefixed [`tcp`] transport, and the hostile-input resource
//! budgets in [`limits`] (DESIGN.md §9).

pub mod framing;
pub mod limits;
pub mod shaped;
pub mod tcp;

pub use framing::{
    dequantize_features, dequantize_features_into, encode_response_into,
    encode_response_v2_into, quantize_features, quantize_features_into, FeatureFrame, Hello, Msg,
    Payload, Request, Response, ResponseV2, ERR_OVERLOADED, RESP_FLAG_NEED_KEYFRAME,
};
pub use limits::{backoff_delay, FrameLimits, GateState, LimitsConfig, RateCap, SessionGate};
pub use shaped::{LinkModel, ShapedWriter, TokenBucket};
pub use tcp::{
    read_msg, read_msg_limited, read_raw_frame, read_raw_frame_limited, write_frame, write_msg,
    write_raw_frame,
};

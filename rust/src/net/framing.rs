//! Wire protocol for the split-policy client/server loop.
//!
//! Both observation formats are **uncompressed uint8 buffers**, exactly as
//! the paper specifies (§4.2): a server-only request carries the full RGBA
//! frame (4·X² bytes); a split request carries the K-channel feature map
//! (K·(X/2ⁿ)² bytes) quantised to u8 with a per-message scale (features are
//! post-ReLU, so [0, scale] covers them).
//!
//! Frame layout: `[u32 len][u8 msg_type][payload…]`, little-endian.

use anyhow::{bail, ensure, Result};

pub const MSG_REQUEST_RAW: u8 = 1;
pub const MSG_REQUEST_FEAT: u8 = 2;
pub const MSG_RESPONSE: u8 = 3;
pub const MSG_HELLO: u8 = 4;

/// Maximum accepted frame body (64 MB — a 4000² RGBA frame is 64 MB).
pub const MAX_FRAME: usize = 64 << 20;

#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Full RGBA observation, x·x·4 bytes (server-only pipeline).
    RawRgba { x: u16, data: Vec<u8> },
    /// Quantised feature map (split pipeline).
    Features { c: u16, h: u16, w: u16, scale: f32, data: Vec<u8> },
}

impl Payload {
    /// Bytes this payload puts on the wire (body only) — the quantity the
    /// paper's bandwidth model counts.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::RawRgba { data, .. } => data.len(),
            Payload::Features { data, .. } => data.len(),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub client: u32,
    pub id: u64,
    pub payload: Payload,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub client: u32,
    pub id: u64,
    pub action: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Hello {
    pub client: u32,
    /// "server-only" | "split"
    pub split: bool,
    /// Shard this session was pinned to. `None` on a client's opening hello;
    /// set by the fleet gateway (and by shard servers in their hello acks)
    /// so clients and health probes can observe placement.
    pub shard: Option<u16>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    Hello(Hello),
    Request(Request),
    Response(Response),
}

fn put_u16(v: &mut Vec<u8>, x: u16) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_u32(v: &mut Vec<u8>, x: u32) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_u64(v: &mut Vec<u8>, x: u64) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_f32(v: &mut Vec<u8>, x: f32) {
    v.extend_from_slice(&x.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.b.len(), "truncated message");
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn done(&self) -> bool {
        self.pos == self.b.len()
    }
}

/// Patch the 4-byte length prefix of a frame assembled by an
/// `encode*_into` writer (everything after the prefix counts).
fn seal_frame(out: &mut [u8]) {
    let len = (out.len() - 4) as u32;
    out[0..4].copy_from_slice(&len.to_le_bytes());
}

impl Msg {
    /// Encode as a length-prefixed frame into a caller-owned buffer
    /// (cleared, then filled; capacity is reused across frames — the
    /// serving reply path pools one buffer per executor).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&[0u8; 4]); // length prefix, sealed below
        match self {
            Msg::Hello(h) => {
                out.push(MSG_HELLO);
                put_u32(out, h.client);
                out.push(h.split as u8);
                match h.shard {
                    Some(s) => {
                        out.push(1);
                        put_u16(out, s);
                    }
                    None => out.push(0),
                }
            }
            Msg::Request(r) => match &r.payload {
                Payload::RawRgba { x, data } => {
                    out.push(MSG_REQUEST_RAW);
                    put_u32(out, r.client);
                    put_u64(out, r.id);
                    put_u16(out, *x);
                    out.extend_from_slice(data);
                }
                Payload::Features { c, h, w, scale, data } => {
                    out.push(MSG_REQUEST_FEAT);
                    put_u32(out, r.client);
                    put_u64(out, r.id);
                    put_u16(out, *c);
                    put_u16(out, *h);
                    put_u16(out, *w);
                    put_f32(out, *scale);
                    out.extend_from_slice(data);
                }
            },
            Msg::Response(r) => {
                out.push(MSG_RESPONSE);
                put_u32(out, r.client);
                put_u64(out, r.id);
                put_u16(out, r.action.len() as u16);
                for a in &r.action {
                    put_f32(out, *a);
                }
            }
        }
        seal_frame(out);
    }

    /// Encode as a length-prefixed frame (allocating convenience over
    /// [`Msg::encode_into`]).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Decode one frame body (`ty` byte + payload, no length prefix).
    pub fn decode(frame: &[u8]) -> Result<Msg> {
        ensure!(!frame.is_empty(), "empty frame");
        let ty = frame[0];
        let mut r = Reader { b: &frame[1..], pos: 0 };
        let msg = match ty {
            MSG_HELLO => {
                let client = r.u32()?;
                let split = r.take(1)?[0] != 0;
                let shard = match r.take(1)?[0] {
                    0 => None,
                    1 => Some(r.u16()?),
                    other => bail!("bad shard tag {other}"),
                };
                Msg::Hello(Hello { client, split, shard })
            }
            MSG_REQUEST_RAW => {
                let client = r.u32()?;
                let id = r.u64()?;
                let x = r.u16()?;
                let need = x as usize * x as usize * 4;
                let data = r.take(need)?.to_vec();
                Msg::Request(Request { client, id, payload: Payload::RawRgba { x, data } })
            }
            MSG_REQUEST_FEAT => {
                let client = r.u32()?;
                let id = r.u64()?;
                let c = r.u16()?;
                let h = r.u16()?;
                let w = r.u16()?;
                let scale = r.f32()?;
                let need = c as usize * h as usize * w as usize;
                let data = r.take(need)?.to_vec();
                Msg::Request(Request {
                    client,
                    id,
                    payload: Payload::Features { c, h, w, scale, data },
                })
            }
            MSG_RESPONSE => {
                let client = r.u32()?;
                let id = r.u64()?;
                let n = r.u16()? as usize;
                let mut action = Vec::with_capacity(n);
                for _ in 0..n {
                    action.push(r.f32()?);
                }
                Msg::Response(Response { client, id, action })
            }
            other => bail!("unknown message type {other}"),
        };
        ensure!(r.done(), "trailing bytes in frame");
        Ok(msg)
    }
}

/// Quantise a float feature map (post-ReLU, >= 0) to u8 with its max as
/// scale, writing into a caller-owned buffer (cleared, then filled;
/// allocates only if capacity is short). The per-pixel division is
/// replaced by one precomputed scale reciprocal. Callers that keep the
/// buffer across frames (bench loops, telemetry) get true reuse; the wire
/// path hands buffer ownership to the message, so it goes through the
/// allocating [`quantize_features`] wrapper and benefits from the
/// reciprocal only.
pub fn quantize_features_into(feat: &[f32], out: &mut Vec<u8>) -> f32 {
    let scale = feat.iter().fold(0.0f32, |a, &b| a.max(b)).max(1e-6);
    let inv = 255.0 / scale;
    out.clear();
    out.reserve(feat.len());
    out.extend(feat.iter().map(|&v| (v * inv).clamp(0.0, 255.0).round() as u8));
    scale
}

/// Quantise a float feature map (post-ReLU, >= 0) to u8 with its max as
/// scale — the uint8 feature buffer the paper transmits.
pub fn quantize_features(feat: &[f32]) -> (f32, Vec<u8>) {
    let mut data = Vec::new();
    let scale = quantize_features_into(feat, &mut data);
    (scale, data)
}

/// Encode a response frame straight from an action slice into a pooled
/// buffer: the reply hot path never materialises a [`Response`] struct or
/// clones the action vector. Byte-identical to
/// `Msg::Response(Response { .. }).encode()`.
pub fn encode_response_into(client: u32, id: u64, action: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.extend_from_slice(&[0u8; 4]);
    out.push(MSG_RESPONSE);
    put_u32(out, client);
    put_u64(out, id);
    put_u16(out, action.len() as u16);
    for a in action {
        put_f32(out, *a);
    }
    seal_frame(out);
}

/// Dequantise a u8 feature payload directly into a caller-provided slice
/// (a batch-matrix row) — the fused dequantise-and-pack step of the
/// serving hot path. A 256-entry stack LUT (one entry per byte value,
/// computed with the exact per-byte expression of
/// [`dequantize_features`]) replaces the per-byte divide, mirroring the
/// per-scale dequant LUT in `shader::compiled`; results are bit-identical
/// to the allocating wrapper.
pub fn dequantize_features_into(scale: f32, data: &[u8], out: &mut [f32]) {
    assert_eq!(data.len(), out.len(), "dequantize into a slice of the wrong length");
    let mut lut = [0.0f32; 256];
    for (b, v) in lut.iter_mut().enumerate() {
        *v = b as f32 / 255.0 * scale;
    }
    for (o, &b) in out.iter_mut().zip(data.iter()) {
        *o = lut[b as usize];
    }
}

/// Dequantise back to floats (allocating wrapper over
/// [`dequantize_features_into`]).
pub fn dequantize_features(scale: f32, data: &[u8]) -> Vec<f32> {
    let mut out = vec![0.0f32; data.len()];
    dequantize_features_into(scale, data, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_request_roundtrip_and_size() {
        let x = 84u16;
        let data = vec![7u8; 84 * 84 * 4];
        let msg = Msg::Request(Request {
            client: 3,
            id: 42,
            payload: Payload::RawRgba { x, data: data.clone() },
        });
        let enc = msg.encode();
        // wire size = 4 len + 1 type + 4 client + 8 id + 2 x + body
        assert_eq!(enc.len(), 4 + 1 + 4 + 8 + 2 + 84 * 84 * 4);
        let dec = Msg::decode(&enc[4..]).unwrap();
        assert_eq!(dec, msg);
        if let Msg::Request(r) = dec {
            // the paper's 4X^2 model
            assert_eq!(r.payload.wire_bytes(), 4 * 84 * 84);
        }
    }

    #[test]
    fn feature_request_roundtrip_and_size() {
        let (c, h, w) = (4u16, 11u16, 11u16);
        let data = vec![1u8; 4 * 11 * 11];
        let msg = Msg::Request(Request {
            client: 0,
            id: 7,
            payload: Payload::Features { c, h, w, scale: 3.25, data },
        });
        let enc = msg.encode();
        let dec = Msg::decode(&enc[4..]).unwrap();
        assert_eq!(dec, msg);
        if let Msg::Request(r) = dec {
            // the paper's K(X/2^n)^2 model
            assert_eq!(r.payload.wire_bytes(), 4 * 11 * 11);
        }
    }

    #[test]
    fn response_and_hello_roundtrip() {
        for msg in [
            Msg::Response(Response { client: 1, id: 9, action: vec![0.5, -1.25] }),
            Msg::Hello(Hello { client: 12, split: true, shard: None }),
            Msg::Hello(Hello { client: 12, split: false, shard: None }),
            Msg::Hello(Hello { client: 7, split: true, shard: Some(3) }),
            Msg::Hello(Hello { client: 7, split: false, shard: Some(u16::MAX) }),
        ] {
            let enc = msg.encode();
            assert_eq!(Msg::decode(&enc[4..]).unwrap(), msg);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Msg::decode(&[]).is_err());
        assert!(Msg::decode(&[99, 0, 0]).is_err());
        // truncated raw request
        let msg = Msg::Request(Request {
            client: 0,
            id: 1,
            payload: Payload::RawRgba { x: 10, data: vec![0; 400] },
        });
        let enc = msg.encode();
        assert!(Msg::decode(&enc[4..enc.len() - 5]).is_err());
        // trailing bytes
        let mut extended = enc[4..].to_vec();
        extended.push(0);
        assert!(Msg::decode(&extended).is_err());
    }

    #[test]
    fn quantization_roundtrip_error_bounded() {
        let feat: Vec<f32> = (0..100).map(|i| (i as f32 * 0.37) % 5.0).collect();
        let (scale, q) = quantize_features(&feat);
        let back = dequantize_features(scale, &q);
        for (a, b) in feat.iter().zip(&back) {
            assert!((a - b).abs() <= scale / 255.0 * 0.5 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn quantize_into_reuses_buffer_and_matches_wrapper() {
        let feat: Vec<f32> = (0..64).map(|i| (i as f32 * 0.11) % 3.0).collect();
        let (scale_a, q_a) = quantize_features(&feat);
        let mut buf = Vec::new();
        let scale_b = quantize_features_into(&feat, &mut buf);
        assert_eq!(scale_a, scale_b);
        assert_eq!(q_a, buf);
        // refill with a shorter input: buffer shrinks logically, keeps capacity
        let cap = buf.capacity();
        let short = [0.5f32; 8];
        quantize_features_into(&short, &mut buf);
        assert_eq!(buf.len(), 8);
        assert!(buf.capacity() >= cap);
    }

    #[test]
    fn encode_into_reuses_buffer_and_matches_encode() {
        let msgs = [
            Msg::Hello(Hello { client: 7, split: true, shard: Some(3) }),
            Msg::Request(Request {
                client: 1,
                id: 2,
                payload: Payload::Features { c: 4, h: 3, w: 3, scale: 1.5, data: vec![5; 36] },
            }),
            Msg::Request(Request {
                client: 1,
                id: 3,
                payload: Payload::RawRgba { x: 2, data: vec![9; 16] },
            }),
            Msg::Response(Response { client: 4, id: 9, action: vec![0.5, -1.0, 2.0] }),
        ];
        let mut buf = Vec::new();
        for m in &msgs {
            m.encode_into(&mut buf);
            assert_eq!(buf, m.encode());
            assert_eq!(Msg::decode(&buf[4..]).unwrap(), *m);
        }
        // the buffer shrinks logically between frames but keeps capacity
        let cap = buf.capacity();
        msgs[0].encode_into(&mut buf);
        assert!(buf.capacity() >= cap);
    }

    #[test]
    fn encode_response_into_matches_msg_encode() {
        let mut buf = vec![0xAA; 3]; // stale content must be discarded
        encode_response_into(12, 99, &[0.25, -3.5], &mut buf);
        let via_msg =
            Msg::Response(Response { client: 12, id: 99, action: vec![0.25, -3.5] }).encode();
        assert_eq!(buf, via_msg);
        // empty action (the back-pressure rejection reply)
        encode_response_into(1, 2, &[], &mut buf);
        assert_eq!(buf, Msg::Response(Response { client: 1, id: 2, action: vec![] }).encode());
    }

    #[test]
    fn dequantize_into_bit_exact_with_wrapper() {
        let data: Vec<u8> = (0..=255).collect();
        for scale in [1e-6f32, 0.37, 1.0, 3.1415, 255.0] {
            let legacy = dequantize_features(scale, &data);
            let mut row = vec![f32::NAN; data.len()];
            dequantize_features_into(scale, &data, &mut row);
            assert_eq!(legacy, row, "scale {scale}");
        }
    }

    #[test]
    #[should_panic(expected = "wrong length")]
    fn dequantize_into_rejects_wrong_length() {
        let mut row = [0.0f32; 3];
        dequantize_features_into(1.0, &[1, 2], &mut row);
    }

    #[test]
    fn quantization_of_zeros() {
        let (scale, q) = quantize_features(&[0.0; 8]);
        assert!(scale > 0.0);
        assert!(q.iter().all(|&b| b == 0));
    }

    #[test]
    fn split_vs_raw_wire_ratio_matches_paper_model() {
        // X=84, n=3, K=4: raw/feat = 4X^2 / K(X/8)^2
        let raw = 4 * 84 * 84;
        let feat = 4 * 11 * 11;
        let ratio = raw as f64 / feat as f64;
        assert!((ratio - 58.3).abs() < 1.0, "{ratio}");
    }
}

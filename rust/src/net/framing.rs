//! Wire protocol for the split-policy client/server loop.
//!
//! The v1 observation formats are **uncompressed uint8 buffers**, exactly
//! as the paper specifies (§4.2): a server-only request carries the full
//! RGBA frame (4·X² bytes); a split request carries the K-channel feature
//! map (K·(X/2ⁿ)² bytes) quantised to u8 with a per-message scale
//! (features are post-ReLU, so [0, scale] covers them).
//!
//! Sessions that negotiate a codec in the `Hello` handshake (the `codec`
//! byte, echoed by the server's ack) instead ship features as versioned
//! [`Payload::FeaturesV2`] frames — codec id, mode flags, quantisation
//! ceiling, and chain sequence number alongside the entropy-packed payload
//! (`crate::codec`, DESIGN.md §7) — and receive [`ResponseV2`] acks
//! carrying the codec feedback (need-keyframe + queue wait) that closes
//! the rate-control loop. Raw-route and flat-codec clients keep the v1
//! frames byte for byte.
//!
//! Frame layout: `[u32 len][u8 msg_type][payload…]`, little-endian.

use anyhow::{bail, ensure, Result};

pub const MSG_REQUEST_RAW: u8 = 1;
pub const MSG_REQUEST_FEAT: u8 = 2;
pub const MSG_RESPONSE: u8 = 3;
pub const MSG_HELLO: u8 = 4;
/// Versioned feature request (negotiated codec; see `crate::codec`).
pub const MSG_REQUEST_FEAT_V2: u8 = 5;
/// Response with codec feedback (ack of a [`MSG_REQUEST_FEAT_V2`] frame).
pub const MSG_RESPONSE_V2: u8 = 6;
/// Experience frame: a v2 feature frame plus reward/done telemetry for
/// the online learning loop (`crate::learn`, DESIGN.md §8). Requires the
/// [`CAP_EXPERIENCE`] capability negotiated in the `Hello` handshake.
pub const MSG_EXPERIENCE: u8 = 7;
/// Response to an experience frame: action + policy version stamps.
pub const MSG_RESPONSE_LEARN: u8 = 8;
/// Explicit protocol error (e.g. an experience frame on a session that
/// never negotiated [`CAP_EXPERIENCE`]).
pub const MSG_ERROR: u8 = 9;
/// Policy fan-out: a versioned flat parameter vector.
pub const MSG_POLICY: u8 = 10;

/// [`ResponseV2::flags`] bit: the server could not decode the frame
/// (chain break, stale base, corrupt payload) — the client must send a
/// keyframe next.
pub const RESP_FLAG_NEED_KEYFRAME: u8 = 1;
/// [`ResponseLearn::flags`] bit: the action was rejected because the
/// acting policy version trailed the latest published version by more
/// than the fleet's staleness bound (`max_lag`). The action vector is
/// empty; the client must retry once the shard resyncs.
pub const RESP_FLAG_STALE: u8 = 2;

/// [`Hello::caps`] bit: the session may carry [`MSG_EXPERIENCE`] frames.
/// The server's ack masks the request down to what it supports; a client
/// whose bit comes back cleared falls back to inference-only frames.
pub const CAP_EXPERIENCE: u8 = 1;

/// [`Hello::caps`] bit: every trace-eligible frame on the session (both
/// directions) carries the fixed-size per-decision trace trailer
/// (`crate::trace`, DESIGN.md §12). Negotiated exactly like
/// [`CAP_EXPERIENCE`]: the client requests, the ack masks, and
/// `net::limits` widens the per-type caps by the trailer size only after
/// the grant — a hostile length can never buy the allowance unnegotiated.
pub const CAP_TRACE: u8 = 2;

/// [`ErrorMsg::code`]: experience frame on a session without the
/// negotiated [`CAP_EXPERIENCE`] capability.
pub const ERR_EXPERIENCE_UNSUPPORTED: u8 = 1;

/// [`ErrorMsg::code`]: the endpoint is shedding load (admission cap or
/// per-client rate cap exceeded — `net::limits`, DESIGN.md §9). The
/// request was *not* processed; the client must back off with jittered
/// retry ([`crate::net::limits::backoff_delay`]) instead of hammering.
pub const ERR_OVERLOADED: u8 = 2;

/// [`ExperienceFrame::flags`] bit: the frame carries the reward/done of
/// the previous action (absent only on the first frame of a stream).
pub const EXP_HAS_REWARD: u8 = 1;
/// [`ExperienceFrame::flags`] bit: the previous action ended its episode.
pub const EXP_DONE: u8 = 2;
/// [`ExperienceFrame::flags`] bit: the episode ended by termination (not
/// time-limit truncation) — the GAE bootstrap distinction.
pub const EXP_TERMINATED: u8 = 4;
/// [`ExperienceFrame::flags`] bit: this observation opens a new episode
/// (step must be 0).
pub const EXP_EP_START: u8 = 8;

/// Maximum accepted frame body (64 MB — a 4000² RGBA frame is 64 MB).
pub const MAX_FRAME: usize = 64 << 20;

/// A versioned feature frame: the negotiated-codec wire format
/// (DESIGN.md §7). `data` is the codec payload — a raw or entropy-packed
/// keyframe, or packed residuals against the previous frame — and decodes
/// through `crate::codec::Decoders` into the exact `[0, qmax]` quantised
/// frame the client produced.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureFrame {
    pub c: u16,
    pub h: u16,
    pub w: u16,
    /// codec id (`crate::codec::{CODEC_FLAT, CODEC_DELTA}`)
    pub codec: u8,
    /// mode flags (`crate::codec::{FLAG_KEYFRAME, FLAG_RAW}`)
    pub flags: u8,
    /// quantisation ceiling: values live in `[0, qmax]`
    pub qmax: u8,
    /// chain sequence number (deltas must advance it by exactly one)
    pub seq: u32,
    pub scale: f32,
    pub data: Vec<u8>,
}

impl FeatureFrame {
    /// Flattened feature element count (`c·h·w`).
    pub fn feat_len(&self) -> usize {
        self.c as usize * self.h as usize * self.w as usize
    }
}

/// An experience frame: a codec feature frame (the observation at
/// (`ep`, `step`)) plus the reward/done outcome of the *previous* action
/// (DESIGN.md §8). The (episode, step) pair is the exactly-once sequence
/// key the shard's `learn::ExperienceBuffer` completes transitions by.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperienceFrame {
    pub feat: FeatureFrame,
    pub ep: u32,
    pub step: u32,
    /// `EXP_*` bits
    pub flags: u8,
    /// reward of the previous action (valid when [`EXP_HAS_REWARD`])
    pub reward: f32,
}

impl ExperienceFrame {
    pub fn has_reward(&self) -> bool {
        self.flags & EXP_HAS_REWARD != 0
    }
    pub fn done(&self) -> bool {
        self.flags & EXP_DONE != 0
    }
    pub fn terminated(&self) -> bool {
        self.flags & EXP_TERMINATED != 0
    }
    pub fn ep_start(&self) -> bool {
        self.flags & EXP_EP_START != 0
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Full RGBA observation, x·x·4 bytes (server-only pipeline).
    RawRgba { x: u16, data: Vec<u8> },
    /// Quantised feature map (split pipeline, flat v1 format).
    Features { c: u16, h: u16, w: u16, scale: f32, data: Vec<u8> },
    /// Codec-encoded feature map (split pipeline, negotiated format).
    FeaturesV2(FeatureFrame),
    /// Feature frame + reward telemetry (online learning loop).
    Experience(ExperienceFrame),
}

impl Payload {
    /// Bytes this payload puts on the wire (body only) — the quantity the
    /// paper's bandwidth model counts. Experience frames also count their
    /// reward telemetry (ep + step + flags + reward = 13 bytes).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::RawRgba { data, .. } => data.len(),
            Payload::Features { data, .. } => data.len(),
            Payload::FeaturesV2(f) => f.data.len(),
            Payload::Experience(e) => e.feat.data.len() + 13,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub client: u32,
    pub id: u64,
    pub payload: Payload,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub client: u32,
    pub id: u64,
    pub action: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Hello {
    pub client: u32,
    /// "server-only" | "split"
    pub split: bool,
    /// Feature-codec negotiation: the codec id the client requests for its
    /// split-route frames; the server's ack echoes the id it accepts (a
    /// server that does not know the id echoes `CODEC_FLAT`, and the
    /// session falls back to the v1 format). Raw-route sessions leave it 0.
    pub codec: u8,
    /// Capability negotiation bits (`CAP_*`): the client requests, the
    /// server's ack masks down to the intersection it supports. A
    /// capability the ack clears must not appear on the session — servers
    /// answer violations with an explicit [`ErrorMsg`] rather than
    /// silently dropping fields.
    pub caps: u8,
    /// Shard this session was pinned to. `None` on a client's opening hello;
    /// set by the fleet gateway (and by shard servers in their hello acks)
    /// so clients and health probes can observe placement.
    pub shard: Option<u16>,
    /// Topology epoch this placement was computed under (DESIGN.md §10).
    /// `None` on a client's first hello; the gateway stamps its current
    /// epoch into every ack and re-route, and a client echoes the last
    /// epoch it saw so servers can refuse stale or forged re-route
    /// instructions. Encodes as extended shard tags (2/3), so a hello
    /// without an epoch is byte-identical to the pre-epoch format.
    pub epoch: Option<u64>,
}

/// Response carrying codec feedback — the ack half of the rate-control
/// loop. `seq` echoes the request frame's chain sequence number;
/// `queue_wait_us` is the server-side queue wait (subtracted from the
/// client's latency sample so server congestion never masquerades as link
/// congestion).
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseV2 {
    pub client: u32,
    pub id: u64,
    pub seq: u32,
    /// [`RESP_FLAG_NEED_KEYFRAME`]
    pub flags: u8,
    pub queue_wait_us: u32,
    pub action: Vec<f32>,
}

impl ResponseV2 {
    pub fn need_keyframe(&self) -> bool {
        self.flags & RESP_FLAG_NEED_KEYFRAME != 0
    }
}

/// Ack of an experience frame: the action plus policy version stamps.
/// `acting_version` is the version that computed the action;
/// `latest_version` is the newest version published fleet-wide (stamped
/// by the gateway on the way back, so clients observe their lag). A
/// stale-rejected action arrives with [`RESP_FLAG_STALE`] and an empty
/// action vector.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseLearn {
    pub client: u32,
    pub id: u64,
    /// echoes the request frame's codec chain sequence number
    pub seq: u32,
    /// `RESP_FLAG_NEED_KEYFRAME` | `RESP_FLAG_STALE`
    pub flags: u8,
    pub acting_version: u64,
    pub latest_version: u64,
    pub action: Vec<f32>,
}

impl ResponseLearn {
    pub fn need_keyframe(&self) -> bool {
        self.flags & RESP_FLAG_NEED_KEYFRAME != 0
    }
    pub fn stale(&self) -> bool {
        self.flags & RESP_FLAG_STALE != 0
    }
}

/// Explicit protocol error frame (clean rejection instead of a silent
/// drop; the satellite contract for capability mismatches).
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorMsg {
    pub client: u32,
    /// `ERR_*` code
    pub code: u8,
    pub detail: String,
}

/// Versioned policy fan-out: the flat parameter vector of
/// `rl::native::NativeCore`, stamped with its `learn::PolicyStore`
/// version. Shards publish (gateway assigns the version) and the
/// gateway broadcasts adoptions back down every shard trunk.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySync {
    pub version: u64,
    pub params: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    Hello(Hello),
    Request(Request),
    Response(Response),
    ResponseV2(ResponseV2),
    ResponseLearn(ResponseLearn),
    Error(ErrorMsg),
    Policy(PolicySync),
}

fn put_u16(v: &mut Vec<u8>, x: u16) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_u32(v: &mut Vec<u8>, x: u32) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_u64(v: &mut Vec<u8>, x: u64) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_f32(v: &mut Vec<u8>, x: f32) {
    v.extend_from_slice(&x.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.b.len(), "truncated message");
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    /// Bytes left in the frame — the bound every wire-claimed element
    /// count must clear *before* it buys an allocation (DESIGN.md §9).
    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }
    /// Validate a claimed element count against the bytes actually left,
    /// overflow-safe, so `Vec::with_capacity(n)` can never allocate more
    /// than the frame itself delivered.
    fn claimed(&self, n: usize, elem_bytes: usize) -> Result<usize> {
        match n.checked_mul(elem_bytes) {
            Some(need) if need <= self.remaining() => Ok(n),
            _ => bail!("claimed count {n} exceeds the {} bytes remaining", self.remaining()),
        }
    }
    fn done(&self) -> bool {
        self.pos == self.b.len()
    }
}

/// Patch the 4-byte length prefix of a frame assembled by an
/// `encode*_into` writer (everything after the prefix counts).
fn seal_frame(out: &mut [u8]) {
    let len = (out.len() - 4) as u32;
    out[0..4].copy_from_slice(&len.to_le_bytes());
}

impl Msg {
    /// Encode as a length-prefixed frame into a caller-owned buffer
    /// (cleared, then filled; capacity is reused across frames — the
    /// serving reply path pools one buffer per executor).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&[0u8; 4]); // length prefix, sealed below
        match self {
            Msg::Hello(h) => {
                out.push(MSG_HELLO);
                put_u32(out, h.client);
                out.push(h.split as u8);
                out.push(h.codec);
                out.push(h.caps);
                // tag 0/1: the pre-epoch layout, byte-for-byte; tags 2/3
                // extend it with the topology epoch (DESIGN.md §10)
                match (h.shard, h.epoch) {
                    (None, None) => out.push(0),
                    (Some(s), None) => {
                        out.push(1);
                        put_u16(out, s);
                    }
                    (Some(s), Some(e)) => {
                        out.push(2);
                        put_u16(out, s);
                        put_u64(out, e);
                    }
                    (None, Some(e)) => {
                        out.push(3);
                        put_u64(out, e);
                    }
                }
            }
            Msg::Request(r) => match &r.payload {
                Payload::RawRgba { x, data } => {
                    out.push(MSG_REQUEST_RAW);
                    put_u32(out, r.client);
                    put_u64(out, r.id);
                    put_u16(out, *x);
                    out.extend_from_slice(data);
                }
                Payload::Features { c, h, w, scale, data } => {
                    out.push(MSG_REQUEST_FEAT);
                    put_u32(out, r.client);
                    put_u64(out, r.id);
                    put_u16(out, *c);
                    put_u16(out, *h);
                    put_u16(out, *w);
                    put_f32(out, *scale);
                    out.extend_from_slice(data);
                }
                Payload::FeaturesV2(f) => {
                    out.push(MSG_REQUEST_FEAT_V2);
                    put_u32(out, r.client);
                    put_u64(out, r.id);
                    put_u16(out, f.c);
                    put_u16(out, f.h);
                    put_u16(out, f.w);
                    out.push(f.codec);
                    out.push(f.flags);
                    out.push(f.qmax);
                    put_u32(out, f.seq);
                    put_f32(out, f.scale);
                    put_u32(out, f.data.len() as u32);
                    out.extend_from_slice(&f.data);
                }
                Payload::Experience(e) => {
                    out.push(MSG_EXPERIENCE);
                    put_u32(out, r.client);
                    put_u64(out, r.id);
                    put_u32(out, e.ep);
                    put_u32(out, e.step);
                    out.push(e.flags);
                    put_f32(out, e.reward);
                    let f = &e.feat;
                    put_u16(out, f.c);
                    put_u16(out, f.h);
                    put_u16(out, f.w);
                    out.push(f.codec);
                    out.push(f.flags);
                    out.push(f.qmax);
                    put_u32(out, f.seq);
                    put_f32(out, f.scale);
                    put_u32(out, f.data.len() as u32);
                    out.extend_from_slice(&f.data);
                }
            },
            Msg::Response(r) => {
                out.push(MSG_RESPONSE);
                put_u32(out, r.client);
                put_u64(out, r.id);
                put_u16(out, r.action.len() as u16);
                for a in &r.action {
                    put_f32(out, *a);
                }
            }
            Msg::ResponseV2(r) => {
                out.push(MSG_RESPONSE_V2);
                put_u32(out, r.client);
                put_u64(out, r.id);
                put_u32(out, r.seq);
                out.push(r.flags);
                put_u32(out, r.queue_wait_us);
                put_u16(out, r.action.len() as u16);
                for a in &r.action {
                    put_f32(out, *a);
                }
            }
            Msg::ResponseLearn(r) => {
                out.push(MSG_RESPONSE_LEARN);
                put_u32(out, r.client);
                put_u64(out, r.id);
                put_u32(out, r.seq);
                out.push(r.flags);
                put_u64(out, r.acting_version);
                put_u64(out, r.latest_version);
                put_u16(out, r.action.len() as u16);
                for a in &r.action {
                    put_f32(out, *a);
                }
            }
            Msg::Error(e) => {
                out.push(MSG_ERROR);
                put_u32(out, e.client);
                out.push(e.code);
                put_u16(out, e.detail.len() as u16);
                out.extend_from_slice(e.detail.as_bytes());
            }
            Msg::Policy(p) => {
                out.push(MSG_POLICY);
                put_u64(out, p.version);
                put_u32(out, p.params.len() as u32);
                for w in &p.params {
                    put_f32(out, *w);
                }
            }
        }
        seal_frame(out);
    }

    /// Encode as a length-prefixed frame (allocating convenience over
    /// [`Msg::encode_into`]).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Decode one frame body (`ty` byte + payload, no length prefix).
    pub fn decode(frame: &[u8]) -> Result<Msg> {
        ensure!(!frame.is_empty(), "empty frame");
        let ty = frame[0];
        let mut r = Reader { b: &frame[1..], pos: 0 };
        let msg = match ty {
            MSG_HELLO => {
                let client = r.u32()?;
                let split = r.take(1)?[0] != 0;
                let codec = r.take(1)?[0];
                let caps = r.take(1)?[0];
                let (shard, epoch) = match r.take(1)?[0] {
                    0 => (None, None),
                    1 => (Some(r.u16()?), None),
                    2 => {
                        let s = r.u16()?;
                        (Some(s), Some(r.u64()?))
                    }
                    3 => (None, Some(r.u64()?)),
                    other => bail!("bad shard tag {other}"),
                };
                Msg::Hello(Hello { client, split, codec, caps, shard, epoch })
            }
            MSG_REQUEST_RAW => {
                let client = r.u32()?;
                let id = r.u64()?;
                let x = r.u16()?;
                let need = x as usize * x as usize * 4;
                let data = r.take(need)?.to_vec();
                Msg::Request(Request { client, id, payload: Payload::RawRgba { x, data } })
            }
            MSG_REQUEST_FEAT => {
                let client = r.u32()?;
                let id = r.u64()?;
                let c = r.u16()?;
                let h = r.u16()?;
                let w = r.u16()?;
                let scale = r.f32()?;
                let need = c as usize * h as usize * w as usize;
                let data = r.take(need)?.to_vec();
                Msg::Request(Request {
                    client,
                    id,
                    payload: Payload::Features { c, h, w, scale, data },
                })
            }
            MSG_REQUEST_FEAT_V2 => {
                let client = r.u32()?;
                let id = r.u64()?;
                let c = r.u16()?;
                let h = r.u16()?;
                let w = r.u16()?;
                let codec = r.take(1)?[0];
                let flags = r.take(1)?[0];
                let qmax = r.take(1)?[0];
                let seq = r.u32()?;
                let scale = r.f32()?;
                let dlen = r.u32()? as usize;
                // a codec payload never exceeds the flat frame (the encoder
                // falls back to a raw keyframe), so this bound also rejects
                // forged lengths before the allocation
                let feat_len = c as usize * h as usize * w as usize;
                ensure!(dlen <= feat_len, "codec payload {dlen} > flat frame {feat_len}");
                let data = r.take(dlen)?.to_vec();
                Msg::Request(Request {
                    client,
                    id,
                    payload: Payload::FeaturesV2(FeatureFrame {
                        c,
                        h,
                        w,
                        codec,
                        flags,
                        qmax,
                        seq,
                        scale,
                        data,
                    }),
                })
            }
            MSG_RESPONSE => {
                let client = r.u32()?;
                let id = r.u64()?;
                let n = r.u16()? as usize;
                let n = r.claimed(n, 4)?;
                let mut action = Vec::with_capacity(n);
                for _ in 0..n {
                    action.push(r.f32()?);
                }
                Msg::Response(Response { client, id, action })
            }
            MSG_RESPONSE_V2 => {
                let client = r.u32()?;
                let id = r.u64()?;
                let seq = r.u32()?;
                let flags = r.take(1)?[0];
                let queue_wait_us = r.u32()?;
                let n = r.u16()? as usize;
                let n = r.claimed(n, 4)?;
                let mut action = Vec::with_capacity(n);
                for _ in 0..n {
                    action.push(r.f32()?);
                }
                Msg::ResponseV2(ResponseV2 { client, id, seq, flags, queue_wait_us, action })
            }
            MSG_EXPERIENCE => {
                let client = r.u32()?;
                let id = r.u64()?;
                let ep = r.u32()?;
                let step = r.u32()?;
                let flags = r.take(1)?[0];
                let reward = r.f32()?;
                let c = r.u16()?;
                let h = r.u16()?;
                let w = r.u16()?;
                let codec = r.take(1)?[0];
                let fflags = r.take(1)?[0];
                let qmax = r.take(1)?[0];
                let seq = r.u32()?;
                let scale = r.f32()?;
                let dlen = r.u32()? as usize;
                let feat_len = c as usize * h as usize * w as usize;
                ensure!(dlen <= feat_len, "codec payload {dlen} > flat frame {feat_len}");
                ensure!(
                    flags & EXP_EP_START == 0 || step == 0,
                    "episode-start frame at step {step}"
                );
                let data = r.take(dlen)?.to_vec();
                Msg::Request(Request {
                    client,
                    id,
                    payload: Payload::Experience(ExperienceFrame {
                        feat: FeatureFrame {
                            c,
                            h,
                            w,
                            codec,
                            flags: fflags,
                            qmax,
                            seq,
                            scale,
                            data,
                        },
                        ep,
                        step,
                        flags,
                        reward,
                    }),
                })
            }
            MSG_RESPONSE_LEARN => {
                let client = r.u32()?;
                let id = r.u64()?;
                let seq = r.u32()?;
                let flags = r.take(1)?[0];
                let acting_version = r.u64()?;
                let latest_version = r.u64()?;
                let n = r.u16()? as usize;
                let n = r.claimed(n, 4)?;
                let mut action = Vec::with_capacity(n);
                for _ in 0..n {
                    action.push(r.f32()?);
                }
                Msg::ResponseLearn(ResponseLearn {
                    client,
                    id,
                    seq,
                    flags,
                    acting_version,
                    latest_version,
                    action,
                })
            }
            MSG_ERROR => {
                let client = r.u32()?;
                let code = r.take(1)?[0];
                let n = r.u16()? as usize;
                let detail = String::from_utf8(r.take(n)?.to_vec())
                    .map_err(|_| anyhow::anyhow!("error detail is not utf-8"))?;
                Msg::Error(ErrorMsg { client, code, detail })
            }
            MSG_POLICY => {
                let version = r.u64()?;
                let n = r.u32()? as usize;
                // exact-length contract, overflow-safe: the claimed count
                // is validated before it sizes the allocation
                ensure!(
                    n.checked_mul(4) == Some(r.remaining()),
                    "policy frame length mismatch"
                );
                let mut params = Vec::with_capacity(n);
                for _ in 0..n {
                    params.push(r.f32()?);
                }
                Msg::Policy(PolicySync { version, params })
            }
            other => bail!("unknown message type {other}"),
        };
        ensure!(r.done(), "trailing bytes in frame");
        Ok(msg)
    }
}

/// Quantise a float feature map (post-ReLU, >= 0) to u8 with its max as
/// scale, writing into a caller-owned buffer (cleared, then filled;
/// allocates only if capacity is short). The per-pixel division is
/// replaced by one precomputed scale reciprocal. Callers that keep the
/// buffer across frames (bench loops, telemetry) get true reuse; the wire
/// path hands buffer ownership to the message, so it goes through the
/// allocating [`quantize_features`] wrapper and benefits from the
/// reciprocal only.
pub fn quantize_features_into(feat: &[f32], out: &mut Vec<u8>) -> f32 {
    let scale = feat.iter().fold(0.0f32, |a, &b| a.max(b)).max(1e-6);
    let inv = 255.0 / scale;
    out.clear();
    out.reserve(feat.len());
    out.extend(feat.iter().map(|&v| (v * inv).clamp(0.0, 255.0).round() as u8));
    scale
}

/// Quantise a float feature map (post-ReLU, >= 0) to u8 with its max as
/// scale — the uint8 feature buffer the paper transmits.
pub fn quantize_features(feat: &[f32]) -> (f32, Vec<u8>) {
    let mut data = Vec::new();
    let scale = quantize_features_into(feat, &mut data);
    (scale, data)
}

/// Encode a response frame straight from an action slice into a pooled
/// buffer: the reply hot path never materialises a [`Response`] struct or
/// clones the action vector. Byte-identical to
/// `Msg::Response(Response { .. }).encode()`.
pub fn encode_response_into(client: u32, id: u64, action: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.extend_from_slice(&[0u8; 4]);
    out.push(MSG_RESPONSE);
    put_u32(out, client);
    put_u64(out, id);
    put_u16(out, action.len() as u16);
    for a in action {
        put_f32(out, *a);
    }
    seal_frame(out);
}

/// Encode a codec-feedback response frame straight into a pooled buffer
/// (the [`encode_response_into`] analogue for sessions on the v2 format).
/// Byte-identical to `Msg::ResponseV2(ResponseV2 { .. }).encode()`.
#[allow(clippy::too_many_arguments)]
pub fn encode_response_v2_into(
    client: u32,
    id: u64,
    seq: u32,
    flags: u8,
    queue_wait_us: u32,
    action: &[f32],
    out: &mut Vec<u8>,
) {
    out.clear();
    out.extend_from_slice(&[0u8; 4]);
    out.push(MSG_RESPONSE_V2);
    put_u32(out, client);
    put_u64(out, id);
    put_u32(out, seq);
    out.push(flags);
    put_u32(out, queue_wait_us);
    put_u16(out, action.len() as u16);
    for a in action {
        put_f32(out, *a);
    }
    seal_frame(out);
}

/// Encode a learning response straight into a pooled buffer (the
/// [`encode_response_v2_into`] analogue for experience sessions).
/// Byte-identical to `Msg::ResponseLearn(ResponseLearn { .. }).encode()`.
#[allow(clippy::too_many_arguments)]
pub fn encode_response_learn_into(
    client: u32,
    id: u64,
    seq: u32,
    flags: u8,
    acting_version: u64,
    latest_version: u64,
    action: &[f32],
    out: &mut Vec<u8>,
) {
    out.clear();
    out.extend_from_slice(&[0u8; 4]);
    out.push(MSG_RESPONSE_LEARN);
    put_u32(out, client);
    put_u64(out, id);
    put_u32(out, seq);
    out.push(flags);
    put_u64(out, acting_version);
    put_u64(out, latest_version);
    put_u16(out, action.len() as u16);
    for a in action {
        put_f32(out, *a);
    }
    seal_frame(out);
}

/// Dequantise a u8 feature payload directly into a caller-provided slice
/// (a batch-matrix row) — the fused dequantise-and-pack step of the
/// serving hot path. A 256-entry stack LUT (one entry per byte value,
/// computed with the exact per-byte expression of
/// [`dequantize_features`]) replaces the per-byte divide, mirroring the
/// per-scale dequant LUT in `shader::compiled`; results are bit-identical
/// to the allocating wrapper.
pub fn dequantize_features_into(scale: f32, data: &[u8], out: &mut [f32]) {
    assert_eq!(data.len(), out.len(), "dequantize into a slice of the wrong length");
    let mut lut = [0.0f32; 256];
    for (b, v) in lut.iter_mut().enumerate() {
        *v = b as f32 / 255.0 * scale;
    }
    for (o, &b) in out.iter_mut().zip(data.iter()) {
        *o = lut[b as usize];
    }
}

/// Dequantise back to floats (allocating wrapper over
/// [`dequantize_features_into`]).
pub fn dequantize_features(scale: f32, data: &[u8]) -> Vec<f32> {
    let mut out = vec![0.0f32; data.len()];
    dequantize_features_into(scale, data, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_request_roundtrip_and_size() {
        let x = 84u16;
        let data = vec![7u8; 84 * 84 * 4];
        let msg = Msg::Request(Request {
            client: 3,
            id: 42,
            payload: Payload::RawRgba { x, data: data.clone() },
        });
        let enc = msg.encode();
        // wire size = 4 len + 1 type + 4 client + 8 id + 2 x + body
        assert_eq!(enc.len(), 4 + 1 + 4 + 8 + 2 + 84 * 84 * 4);
        let dec = Msg::decode(&enc[4..]).unwrap();
        assert_eq!(dec, msg);
        if let Msg::Request(r) = dec {
            // the paper's 4X^2 model
            assert_eq!(r.payload.wire_bytes(), 4 * 84 * 84);
        }
    }

    #[test]
    fn feature_request_roundtrip_and_size() {
        let (c, h, w) = (4u16, 11u16, 11u16);
        let data = vec![1u8; 4 * 11 * 11];
        let msg = Msg::Request(Request {
            client: 0,
            id: 7,
            payload: Payload::Features { c, h, w, scale: 3.25, data },
        });
        let enc = msg.encode();
        let dec = Msg::decode(&enc[4..]).unwrap();
        assert_eq!(dec, msg);
        if let Msg::Request(r) = dec {
            // the paper's K(X/2^n)^2 model
            assert_eq!(r.payload.wire_bytes(), 4 * 11 * 11);
        }
    }

    #[test]
    fn response_and_hello_roundtrip() {
        for msg in [
            Msg::Response(Response { client: 1, id: 9, action: vec![0.5, -1.25] }),
            Msg::Hello(Hello { client: 12, split: true, codec: 0, caps: 0, shard: None, epoch: None }),
            Msg::Hello(Hello { client: 12, split: false, codec: 0, caps: 0, shard: None, epoch: None }),
            Msg::Hello(Hello { client: 7, split: true, codec: 1, caps: 0, shard: Some(3), epoch: None }),
            Msg::Hello(Hello {
                client: 7,
                split: true,
                codec: 1,
                caps: CAP_EXPERIENCE,
                shard: None,
                epoch: None,
            }),
            Msg::Hello(Hello {
                client: 7,
                split: false,
                codec: 0,
                caps: 0,
                shard: Some(u16::MAX),
                epoch: None,
            }),
            // tag 2: shard + topology epoch (a gateway re-route ack)
            Msg::Hello(Hello {
                client: 9,
                split: true,
                codec: 1,
                caps: 0,
                shard: Some(4),
                epoch: Some(17),
            }),
            Msg::Hello(Hello {
                client: 9,
                split: true,
                codec: 1,
                caps: 0,
                shard: Some(0),
                epoch: Some(u64::MAX),
            }),
            // tag 3: epoch only (a client echoing its last-seen epoch)
            Msg::Hello(Hello {
                client: 9,
                split: false,
                codec: 0,
                caps: 0,
                shard: None,
                epoch: Some(1),
            }),
        ] {
            let enc = msg.encode();
            assert_eq!(Msg::decode(&enc[4..]).unwrap(), msg);
        }
    }

    #[test]
    fn epochless_hello_keeps_the_pre_epoch_wire_layout() {
        // tags 0 and 1 must stay byte-identical to the format before the
        // epoch extension, so mixed-version fleets interoperate
        let none = Msg::Hello(Hello {
            client: 0x0403_0201,
            split: true,
            codec: 1,
            caps: 2,
            shard: None,
            epoch: None,
        })
        .encode();
        assert_eq!(&none[4..], &[MSG_HELLO, 1, 2, 3, 4, 1, 1, 2, 0]);
        let pinned = Msg::Hello(Hello {
            client: 0x0403_0201,
            split: true,
            codec: 1,
            caps: 2,
            shard: Some(0x0605),
            epoch: None,
        })
        .encode();
        assert_eq!(&pinned[4..], &[MSG_HELLO, 1, 2, 3, 4, 1, 1, 2, 1, 5, 6]);
        // and a truncated epoch body (tag 2 without the 8 epoch bytes)
        // must reject, not under-read
        let mut bad = pinned[4..].to_vec();
        bad[8] = 2; // claim tag 2, supply no epoch
        assert!(Msg::decode(&bad).is_err());
    }

    #[test]
    fn features_v2_roundtrip_and_wire_bytes() {
        let frame = FeatureFrame {
            c: 4,
            h: 11,
            w: 11,
            codec: 1,
            flags: 1,
            qmax: 63,
            seq: 42,
            scale: 2.5,
            data: vec![9; 37],
        };
        let msg = Msg::Request(Request { client: 3, id: 8, payload: Payload::FeaturesV2(frame) });
        let enc = msg.encode();
        // 4 len + 1 type + 4 client + 8 id + 6 dims + 3 codec/flags/qmax +
        // 4 seq + 4 scale + 4 dlen + body
        assert_eq!(enc.len(), 4 + 1 + 4 + 8 + 6 + 3 + 4 + 4 + 4 + 37);
        let dec = Msg::decode(&enc[4..]).unwrap();
        assert_eq!(dec, msg);
        if let Msg::Request(r) = dec {
            // only the codec payload counts against the bandwidth model
            assert_eq!(r.payload.wire_bytes(), 37);
        }
    }

    #[test]
    fn features_v2_rejects_payload_longer_than_the_flat_frame() {
        let frame = FeatureFrame {
            c: 1,
            h: 2,
            w: 2,
            codec: 1,
            flags: 3,
            qmax: 255,
            seq: 1,
            scale: 1.0,
            data: vec![0; 5], // 5 > c·h·w = 4
        };
        let msg = Msg::Request(Request { client: 0, id: 0, payload: Payload::FeaturesV2(frame) });
        let enc = msg.encode();
        assert!(Msg::decode(&enc[4..]).is_err());
    }

    #[test]
    fn response_v2_roundtrip_and_flags() {
        for msg in [
            Msg::ResponseV2(ResponseV2 {
                client: 5,
                id: 77,
                seq: 12,
                flags: 0,
                queue_wait_us: 340,
                action: vec![0.25, -1.0],
            }),
            Msg::ResponseV2(ResponseV2 {
                client: 5,
                id: 78,
                seq: 13,
                flags: RESP_FLAG_NEED_KEYFRAME,
                queue_wait_us: 0,
                action: vec![],
            }),
        ] {
            let enc = msg.encode();
            assert_eq!(Msg::decode(&enc[4..]).unwrap(), msg);
        }
        let r = ResponseV2 {
            client: 0,
            id: 0,
            seq: 0,
            flags: RESP_FLAG_NEED_KEYFRAME,
            queue_wait_us: 0,
            action: vec![],
        };
        assert!(r.need_keyframe());
        assert!(!ResponseV2 { flags: 0, ..r }.need_keyframe());
    }

    #[test]
    fn encode_response_v2_into_matches_msg_encode() {
        let mut buf = vec![0x55; 9]; // stale content must be discarded
        encode_response_v2_into(12, 99, 7, RESP_FLAG_NEED_KEYFRAME, 2500, &[0.5], &mut buf);
        let via_msg = Msg::ResponseV2(ResponseV2 {
            client: 12,
            id: 99,
            seq: 7,
            flags: RESP_FLAG_NEED_KEYFRAME,
            queue_wait_us: 2500,
            action: vec![0.5],
        })
        .encode();
        assert_eq!(buf, via_msg);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Msg::decode(&[]).is_err());
        assert!(Msg::decode(&[99, 0, 0]).is_err());
        // truncated raw request
        let msg = Msg::Request(Request {
            client: 0,
            id: 1,
            payload: Payload::RawRgba { x: 10, data: vec![0; 400] },
        });
        let enc = msg.encode();
        assert!(Msg::decode(&enc[4..enc.len() - 5]).is_err());
        // trailing bytes
        let mut extended = enc[4..].to_vec();
        extended.push(0);
        assert!(Msg::decode(&extended).is_err());
    }

    #[test]
    fn responses_reject_forged_action_counts_before_allocating() {
        // a response claiming 65535 actions but delivering none must be
        // rejected by the remaining-bytes bound, not by running off the
        // end after a 256 KiB allocation
        for ty in [MSG_RESPONSE, MSG_RESPONSE_V2, MSG_RESPONSE_LEARN] {
            let mut body = vec![ty];
            body.extend_from_slice(&7u32.to_le_bytes()); // client
            body.extend_from_slice(&9u64.to_le_bytes()); // id
            if ty != MSG_RESPONSE {
                body.extend_from_slice(&1u32.to_le_bytes()); // seq
                body.push(0); // flags
            }
            match ty {
                MSG_RESPONSE_V2 => body.extend_from_slice(&0u32.to_le_bytes()), // queue wait
                MSG_RESPONSE_LEARN => {
                    body.extend_from_slice(&1u64.to_le_bytes()); // acting
                    body.extend_from_slice(&1u64.to_le_bytes()); // latest
                }
                _ => {}
            }
            body.extend_from_slice(&u16::MAX.to_le_bytes()); // forged count
            assert!(Msg::decode(&body).is_err(), "type {ty} accepted a forged count");
        }
    }

    #[test]
    fn quantization_roundtrip_error_bounded() {
        let feat: Vec<f32> = (0..100).map(|i| (i as f32 * 0.37) % 5.0).collect();
        let (scale, q) = quantize_features(&feat);
        let back = dequantize_features(scale, &q);
        for (a, b) in feat.iter().zip(&back) {
            assert!((a - b).abs() <= scale / 255.0 * 0.5 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn quantize_into_reuses_buffer_and_matches_wrapper() {
        let feat: Vec<f32> = (0..64).map(|i| (i as f32 * 0.11) % 3.0).collect();
        let (scale_a, q_a) = quantize_features(&feat);
        let mut buf = Vec::new();
        let scale_b = quantize_features_into(&feat, &mut buf);
        assert_eq!(scale_a, scale_b);
        assert_eq!(q_a, buf);
        // refill with a shorter input: buffer shrinks logically, keeps capacity
        let cap = buf.capacity();
        let short = [0.5f32; 8];
        quantize_features_into(&short, &mut buf);
        assert_eq!(buf.len(), 8);
        assert!(buf.capacity() >= cap);
    }

    #[test]
    fn encode_into_reuses_buffer_and_matches_encode() {
        let msgs = [
            Msg::Hello(Hello { client: 7, split: true, codec: 1, caps: 0, shard: Some(3), epoch: None }),
            Msg::Request(Request {
                client: 1,
                id: 2,
                payload: Payload::Features { c: 4, h: 3, w: 3, scale: 1.5, data: vec![5; 36] },
            }),
            Msg::Request(Request {
                client: 1,
                id: 3,
                payload: Payload::RawRgba { x: 2, data: vec![9; 16] },
            }),
            Msg::Request(Request {
                client: 2,
                id: 4,
                payload: Payload::FeaturesV2(FeatureFrame {
                    c: 2,
                    h: 3,
                    w: 3,
                    codec: 1,
                    flags: 0,
                    qmax: 127,
                    seq: 5,
                    scale: 0.75,
                    data: vec![3; 7],
                }),
            }),
            Msg::Response(Response { client: 4, id: 9, action: vec![0.5, -1.0, 2.0] }),
            Msg::ResponseV2(ResponseV2 {
                client: 4,
                id: 10,
                seq: 5,
                flags: 0,
                queue_wait_us: 12,
                action: vec![1.5],
            }),
        ];
        let mut buf = Vec::new();
        for m in &msgs {
            m.encode_into(&mut buf);
            assert_eq!(buf, m.encode());
            assert_eq!(Msg::decode(&buf[4..]).unwrap(), *m);
        }
        // the buffer shrinks logically between frames but keeps capacity
        let cap = buf.capacity();
        msgs[0].encode_into(&mut buf);
        assert!(buf.capacity() >= cap);
    }

    fn sample_experience(flags: u8, dlen: usize) -> Msg {
        Msg::Request(Request {
            client: 9,
            id: 1001,
            payload: Payload::Experience(ExperienceFrame {
                feat: FeatureFrame {
                    c: 3,
                    h: 1,
                    w: 1,
                    codec: 1,
                    flags: 1,
                    qmax: 255,
                    seq: 17,
                    scale: 0.97,
                    data: vec![4; dlen],
                },
                ep: 6,
                step: if flags & EXP_EP_START != 0 { 0 } else { 42 },
                flags,
                reward: -7.25,
            }),
        })
    }

    #[test]
    fn experience_roundtrip_size_and_flags() {
        let msg = sample_experience(EXP_HAS_REWARD | EXP_DONE, 3);
        let enc = msg.encode();
        // 4 len + 1 type + 4 client + 8 id + 13 exp (ep/step/flags/reward)
        // + 6 dims + 3 codec/flags/qmax + 4 seq + 4 scale + 4 dlen + body
        assert_eq!(enc.len(), 4 + 1 + 4 + 8 + 13 + 6 + 3 + 4 + 4 + 4 + 3);
        let dec = Msg::decode(&enc[4..]).unwrap();
        assert_eq!(dec, msg);
        let Msg::Request(r) = dec else { panic!("not a request") };
        // telemetry counts against the bandwidth model
        assert_eq!(r.payload.wire_bytes(), 3 + 13);
        let Payload::Experience(e) = r.payload else { panic!("not experience") };
        assert!(e.has_reward() && e.done());
        assert!(!e.terminated() && !e.ep_start());
    }

    #[test]
    fn experience_rejects_oversize_payload_and_bad_ep_start() {
        let over = sample_experience(EXP_HAS_REWARD, 4); // 4 > c·h·w = 3
        let enc = over.encode();
        assert!(Msg::decode(&enc[4..]).is_err());
        // EP_START at a nonzero step is forged: flip the flag on the wire
        let ok = sample_experience(EXP_HAS_REWARD, 3);
        let mut enc = ok.encode();
        // flags byte sits after len(4) + type(1) + client(4) + id(8) + ep(4) + step(4)
        enc[4 + 1 + 4 + 8 + 4 + 4] |= EXP_EP_START;
        assert!(Msg::decode(&enc[4..]).is_err());
    }

    #[test]
    fn response_learn_roundtrip_flags_and_pooled_writer() {
        let msg = Msg::ResponseLearn(ResponseLearn {
            client: 3,
            id: 55,
            seq: 9,
            flags: 0,
            acting_version: 41,
            latest_version: 42,
            action: vec![0.5, -0.25],
        });
        let enc = msg.encode();
        assert_eq!(Msg::decode(&enc[4..]).unwrap(), msg);
        let stale = ResponseLearn {
            client: 3,
            id: 56,
            seq: 10,
            flags: RESP_FLAG_STALE,
            acting_version: 1,
            latest_version: 42,
            action: vec![],
        };
        assert!(stale.stale() && !stale.need_keyframe());
        let kf = ResponseLearn { flags: RESP_FLAG_NEED_KEYFRAME, ..stale.clone() };
        assert!(kf.need_keyframe() && !kf.stale());
        let enc2 = Msg::ResponseLearn(stale.clone()).encode();
        assert_eq!(Msg::decode(&enc2[4..]).unwrap(), Msg::ResponseLearn(stale.clone()));
        let mut buf = vec![0x77; 5];
        encode_response_learn_into(3, 56, 10, RESP_FLAG_STALE, 1, 42, &[], &mut buf);
        assert_eq!(buf, enc2);
    }

    #[test]
    fn error_and_policy_roundtrip() {
        let err = Msg::Error(ErrorMsg {
            client: 11,
            code: ERR_EXPERIENCE_UNSUPPORTED,
            detail: "experience frames not negotiated".into(),
        });
        let enc = err.encode();
        assert_eq!(Msg::decode(&enc[4..]).unwrap(), err);
        let pol = Msg::Policy(PolicySync { version: 17, params: vec![0.5, -1.5, 3.25] });
        let enc = pol.encode();
        // 4 len + 1 type + 8 version + 4 count + 12 params
        assert_eq!(enc.len(), 4 + 1 + 8 + 4 + 12);
        assert_eq!(Msg::decode(&enc[4..]).unwrap(), pol);
        // forged count must be rejected, not mis-sliced
        let mut bad = enc[4..].to_vec();
        bad[9] = 99;
        assert!(Msg::decode(&bad).is_err());
    }

    #[test]
    fn encode_response_into_matches_msg_encode() {
        let mut buf = vec![0xAA; 3]; // stale content must be discarded
        encode_response_into(12, 99, &[0.25, -3.5], &mut buf);
        let via_msg =
            Msg::Response(Response { client: 12, id: 99, action: vec![0.25, -3.5] }).encode();
        assert_eq!(buf, via_msg);
        // empty action (the back-pressure rejection reply)
        encode_response_into(1, 2, &[], &mut buf);
        assert_eq!(buf, Msg::Response(Response { client: 1, id: 2, action: vec![] }).encode());
    }

    #[test]
    fn dequantize_into_bit_exact_with_wrapper() {
        let data: Vec<u8> = (0..=255).collect();
        for scale in [1e-6f32, 0.37, 1.0, 3.1415, 255.0] {
            let legacy = dequantize_features(scale, &data);
            let mut row = vec![f32::NAN; data.len()];
            dequantize_features_into(scale, &data, &mut row);
            assert_eq!(legacy, row, "scale {scale}");
        }
    }

    #[test]
    #[should_panic(expected = "wrong length")]
    fn dequantize_into_rejects_wrong_length() {
        let mut row = [0.0f32; 3];
        dequantize_features_into(1.0, &[1, 2], &mut row);
    }

    #[test]
    fn quantization_of_zeros() {
        let (scale, q) = quantize_features(&[0.0; 8]);
        assert!(scale > 0.0);
        assert!(q.iter().all(|&b| b == 0));
    }

    #[test]
    fn split_vs_raw_wire_ratio_matches_paper_model() {
        // X=84, n=3, K=4: raw/feat = 4X^2 / K(X/8)^2
        let raw = 4 * 84 * 84;
        let feat = 4 * 11 * 11;
        let ratio = raw as f64 / feat as f64;
        assert!((ratio - 58.3).abs() < 1.0, "{ratio}");
    }
}

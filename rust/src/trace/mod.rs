//! Per-decision distributed tracing (DESIGN.md §12): a compact trace
//! context — one `u64` id plus ten stage timestamps — minted by the client
//! when an observation is ready, carried on the wire through every hop of
//! the serving stack, and completed when the action arrives back.
//!
//! ## Wire format
//!
//! The context rides as a fixed [`TRACE_WIRE_BYTES`]-byte **trailer**
//! appended after the canonical message body:
//!
//! ```text
//! [tag u8 = TRACE_TAG][trace_id u64 LE][stamp[0] u64 LE]…[stamp[9] u64 LE]
//! ```
//!
//! The canonical `Msg` encoding is untouched: `Msg::decode` still rejects
//! trailing bytes, so every hostile-wire and fuzz invariant over the base
//! format holds verbatim. Trace-aware endpoints peel the trailer with
//! [`split_trailer`] *before* decoding and append it with [`append_trace`]
//! / [`append_trailer`] *after* encoding. The trailer only appears on
//! sessions that negotiated the `CAP_TRACE` Hello capability, and only on
//! trace-eligible types ([`trace_eligible`]: the four request payloads and
//! the three response kinds — never Hello/Error/Policy). `net::limits`
//! widens the per-type caps by exactly [`TRACE_WIRE_BYTES`] on such
//! sessions, so a hostile length still cannot buy an oversized allocation.
//!
//! Intermediaries (the fleet gateway) never decode: [`stamp_body_tail`]
//! patches one stamp in place at a fixed offset from the end of the body.
//!
//! ## Clocks
//!
//! Stamps are nanoseconds. Threaded runs stamp through the process-wide
//! monotonic epoch ([`now_ns`] over the `Clock` seam); sim runs stamp
//! virtual time directly ([`virtual_ns`]), so same-seed scenario runs
//! produce byte-identical traces.
//!
//! ## Recording
//!
//! [`Ring`] is a preallocated flight recorder: fixed capacity, overwrite
//! oldest, zero steady-state allocations (`TraceCtx` is `Copy`). Export —
//! [`write_jsonl`], [`exemplar_table`] — is pull-based and allocates only
//! at dump time.

use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::net::framing::{
    MSG_EXPERIENCE, MSG_REQUEST_FEAT, MSG_REQUEST_FEAT_V2, MSG_REQUEST_RAW, MSG_RESPONSE,
    MSG_RESPONSE_LEARN, MSG_RESPONSE_V2,
};
use crate::sim::clock::ClockHandle;

/// Stage indices into [`TraceCtx::stamps`], in causal order.
pub const STAGE_MINT: usize = 0; // client: observation ready, span opened
pub const STAGE_ENCODE: usize = 1; // client: payload encoded
pub const STAGE_SEND: usize = 2; // client: frame handed to the wire
pub const STAGE_GW_FORWARD: usize = 3; // gateway: request forwarded upstream
pub const STAGE_ENQUEUE: usize = 4; // shard reader: work enqueued
pub const STAGE_DEQUEUE: usize = 5; // shard executor: batch formed
pub const STAGE_PACK: usize = 6; // arena packed
pub const STAGE_EXECUTE: usize = 7; // policy executed
pub const STAGE_REPLY: usize = 8; // reply frame written
pub const STAGE_RECV: usize = 9; // client: response received, span closed
/// Number of stamp slots in a trace context.
pub const N_STAGES: usize = 10;

/// Stamp-slot names, indexed by the `STAGE_*` constants.
pub const STAGE_NAMES: [&str; N_STAGES] = [
    "mint", "encode", "send", "gw_forward", "enqueue", "dequeue", "pack", "execute", "reply",
    "recv",
];

/// First byte of the wire trailer. Anything else at the trailer offset on
/// a trace-negotiated session is a protocol error.
pub const TRACE_TAG: u8 = 1;

/// Exact wire size of the trailer: tag + id + `N_STAGES` stamps.
pub const TRACE_WIRE_BYTES: usize = 1 + 8 + 8 * N_STAGES;

/// Message types that may carry a trace trailer: the four request payload
/// types and the three response kinds. Hello, Error and Policy frames
/// never carry one (negotiation and control traffic is not a decision).
pub const TRACE_ELIGIBLE: [u8; 7] = [
    MSG_REQUEST_RAW,
    MSG_REQUEST_FEAT,
    MSG_REQUEST_FEAT_V2,
    MSG_EXPERIENCE,
    MSG_RESPONSE,
    MSG_RESPONSE_V2,
    MSG_RESPONSE_LEARN,
];

/// Whether a message type may carry a trace trailer.
pub fn trace_eligible(ty: u8) -> bool {
    TRACE_ELIGIBLE.contains(&ty)
}

/// One decision's span: a trace id plus one nanosecond stamp per stage.
/// `Copy` and fixed-size by design — it moves through channels, rings and
/// the wire without touching the allocator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TraceCtx {
    pub id: u64,
    /// Nanosecond stamps indexed by the `STAGE_*` constants; 0 = unset.
    pub stamps: [u64; N_STAGES],
}

impl TraceCtx {
    /// Open a span: stamp [`STAGE_MINT`] at `ns`.
    pub fn mint(id: u64, ns: u64) -> TraceCtx {
        let mut c = TraceCtx { id, stamps: [0; N_STAGES] };
        c.stamps[STAGE_MINT] = ns;
        c
    }

    /// Record `ns` into `stage` (last writer wins — a retransmitted frame
    /// re-stamps its send-side stages).
    pub fn stamp(&mut self, stage: usize, ns: u64) {
        self.stamps[stage] = ns;
    }

    /// Append the wire trailer ([`TRACE_WIRE_BYTES`] bytes) to `out`.
    pub fn write_wire(&self, out: &mut Vec<u8>) {
        out.push(TRACE_TAG);
        out.extend_from_slice(&self.id.to_le_bytes());
        for s in &self.stamps {
            out.extend_from_slice(&s.to_le_bytes());
        }
    }

    /// Parse a trailer from exactly [`TRACE_WIRE_BYTES`] bytes.
    pub fn read_wire(b: &[u8]) -> Result<TraceCtx> {
        ensure!(b.len() == TRACE_WIRE_BYTES, "trace trailer is {} bytes, want {TRACE_WIRE_BYTES}", b.len());
        ensure!(b[0] == TRACE_TAG, "trace trailer tag {} (want {TRACE_TAG})", b[0]);
        let id = u64::from_le_bytes(b[1..9].try_into().unwrap());
        let mut stamps = [0u64; N_STAGES];
        for (i, s) in stamps.iter_mut().enumerate() {
            let off = 9 + 8 * i;
            *s = u64::from_le_bytes(b[off..off + 8].try_into().unwrap());
        }
        Ok(TraceCtx { id, stamps })
    }

    /// Span length so far: latest stamp − mint. For a closed span this is
    /// the end-to-end latency (recv − mint); for a server-side view (whose
    /// last stamp is reply) it is the span up to that hop, so partial
    /// recordings still sort meaningfully in exemplar dumps.
    pub fn total_ns(&self) -> u64 {
        let last = self.stamps.iter().copied().max().unwrap_or(0);
        last.saturating_sub(self.stamps[STAGE_MINT])
    }

    /// Decompose a *closed* span into the seven per-stage durations.
    /// Saturating throughout, so a hop that never stamped (e.g. no gateway
    /// in the path) degrades to zero rather than wrapping.
    pub fn stages(&self) -> StageNs {
        let s = &self.stamps;
        let d = |a: usize, b: usize| s[b].saturating_sub(s[a]);
        StageNs {
            ns: [
                d(STAGE_MINT, STAGE_ENCODE),
                d(STAGE_SEND, STAGE_ENQUEUE),
                d(STAGE_ENQUEUE, STAGE_DEQUEUE),
                d(STAGE_DEQUEUE, STAGE_PACK),
                d(STAGE_PACK, STAGE_EXECUTE),
                d(STAGE_EXECUTE, STAGE_REPLY),
                d(STAGE_REPLY, STAGE_RECV),
            ],
        }
    }
}

/// Number of derived stage durations a span decomposes into.
pub const N_STAGE_KINDS: usize = 7;

/// Names of the derived durations, indexed like [`StageNs::ns`].
pub const STAGE_KIND_NAMES: [&str; N_STAGE_KINDS] =
    ["encode", "wire_up", "queue", "pack", "execute", "reply", "wire_down"];

/// Per-stage nanosecond totals — one span's decomposition, or an
/// accumulator over many (the autoscaler's attribution feed).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StageNs {
    /// Indexed by [`STAGE_KIND_NAMES`].
    pub ns: [u64; N_STAGE_KINDS],
}

impl StageNs {
    /// Accumulate another decomposition (saturating).
    pub fn add(&mut self, other: &StageNs) {
        for (a, b) in self.ns.iter_mut().zip(other.ns.iter()) {
            *a = a.saturating_add(*b);
        }
    }

    /// Accumulate one closed span.
    pub fn accumulate(&mut self, ctx: &TraceCtx) {
        self.add(&ctx.stages());
    }

    /// Sum of all stages.
    pub fn total(&self) -> u64 {
        self.ns.iter().fold(0u64, |a, &b| a.saturating_add(b))
    }

    /// Combined wire time (both directions).
    pub fn wire(&self) -> u64 {
        self.ns[1].saturating_add(self.ns[6])
    }

    /// Shard queue wait.
    pub fn queue(&self) -> u64 {
        self.ns[2]
    }

    /// The stage holding the largest share, by name (`None` when empty).
    /// Ties resolve to the earliest stage, deterministically.
    pub fn dominant(&self) -> Option<&'static str> {
        let (mut best, mut at) = (0u64, None);
        for (i, &v) in self.ns.iter().enumerate() {
            if v > best {
                best = v;
                at = Some(STAGE_KIND_NAMES[i]);
            }
        }
        at
    }

    /// Windowed delta against an earlier cumulative snapshot (saturating,
    /// so a counter reset degrades to zero instead of wrapping).
    pub fn delta(&self, prev: &StageNs) -> StageNs {
        let mut out = StageNs::default();
        for (i, o) in out.ns.iter_mut().enumerate() {
            *o = self.ns[i].saturating_sub(prev.ns[i]);
        }
        out
    }
}

/// Peel a trace trailer off a message body: `(canonical body, ctx)`.
///
/// Strict by contract — callers invoke this only on sessions that
/// negotiated `CAP_TRACE`, where every trace-eligible frame MUST carry a
/// trailer; a missing or malformed one is a protocol error, exactly like
/// an undecodable body.
pub fn split_trailer(body: &[u8]) -> Result<(&[u8], TraceCtx)> {
    ensure!(!body.is_empty(), "empty frame cannot carry a trace trailer");
    ensure!(trace_eligible(body[0]), "message type {} is not trace-eligible", body[0]);
    if body.len() <= TRACE_WIRE_BYTES {
        bail!("frame too short ({} bytes) for a trace trailer", body.len());
    }
    let base = body.len() - TRACE_WIRE_BYTES;
    let ctx = TraceCtx::read_wire(&body[base..])?;
    Ok((&body[..base], ctx))
}

/// Append a trailer to a prefix-less message body (the sim's frame
/// currency).
pub fn append_trailer(body: &mut Vec<u8>, ctx: &TraceCtx) {
    debug_assert!(body.first().is_some_and(|&t| trace_eligible(t)));
    ctx.write_wire(body);
}

/// Append a trailer to a full length-prefixed frame (the threaded stack's
/// currency: `[u32 len][type][payload…]`) and re-seal the prefix. Works on
/// the pooled reply buffers unchanged — steady-state capacity absorbs the
/// extra [`TRACE_WIRE_BYTES`], so the hot path stays allocation-free.
pub fn append_trace(frame: &mut Vec<u8>, ctx: &TraceCtx) {
    debug_assert!(frame.len() > 4 && trace_eligible(frame[4]));
    ctx.write_wire(frame);
    let len = (frame.len() - 4) as u32;
    frame[0..4].copy_from_slice(&len.to_le_bytes());
}

/// Patch one stamp in place at the tail of a message body, without
/// decoding — the gateway's forward-pump hook. Returns `false` (leaving
/// the body untouched) when the body cannot be carrying a trailer.
///
/// Callers gate this on sessions that negotiated `CAP_TRACE`, where
/// honest clients always attach a trailer; the residual false-positive (a
/// trace-negotiated client sending a traceless eligible frame whose
/// payload happens to end in [`TRACE_TAG`] at the trailer offset) can only
/// corrupt that client's own payload, never another session's.
pub fn stamp_body_tail(body: &mut [u8], stage: usize, ns: u64) -> bool {
    debug_assert!(stage < N_STAGES);
    if body.len() <= TRACE_WIRE_BYTES || !trace_eligible(body[0]) {
        return false;
    }
    let base = body.len() - TRACE_WIRE_BYTES;
    if body[base] != TRACE_TAG {
        return false;
    }
    let off = base + 1 + 8 + 8 * stage;
    body[off..off + 8].copy_from_slice(&ns.to_le_bytes());
    true
}

/// Like [`stamp_body_tail`] but over a full length-prefixed frame.
pub fn stamp_frame_tail(frame: &mut [u8], stage: usize, ns: u64) -> bool {
    if frame.len() <= 4 {
        return false;
    }
    stamp_body_tail(&mut frame[4..], stage, ns)
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds of `at` since the process-wide trace epoch (the first
/// instant this function ever saw). Saturates to zero for instants that
/// race the epoch's initialisation.
pub fn ns_since_epoch(at: Instant) -> u64 {
    let e = *EPOCH.get_or_init(|| at);
    at.saturating_duration_since(e).as_nanos() as u64
}

/// Current trace timestamp through the `Clock` seam (threaded stamps).
pub fn now_ns(clock: &ClockHandle) -> u64 {
    ns_since_epoch(clock.now())
}

/// Virtual-time trace timestamp (sim stamps): seconds of virtual time,
/// rounded to whole nanoseconds — a pure function of the event time, so
/// same-seed runs reproduce stamps bit-for-bit.
pub fn virtual_ns(t_secs: f64) -> u64 {
    (t_secs * 1e9).round() as u64
}

/// Flight-recorder ring: preallocated, overwrite-oldest, `Copy` entries —
/// recording never allocates after construction. "Always on, sampled
/// export": every decision is recorded, the ring's capacity bounds what is
/// exportable, and dumps ([`Ring::to_vec`], [`Ring::slowest`]) allocate
/// only when asked.
#[derive(Debug, Clone)]
pub struct Ring {
    buf: Vec<TraceCtx>,
    cap: usize,
    next: usize,
    len: usize,
}

impl Ring {
    /// A ring retaining the last `cap` spans (`cap` ≥ 1), fully
    /// preallocated up front.
    pub fn with_capacity(cap: usize) -> Ring {
        let cap = cap.max(1);
        Ring { buf: vec![TraceCtx::default(); cap], cap, next: 0, len: 0 }
    }

    /// Record a span, overwriting the oldest once full. Never allocates.
    pub fn push(&mut self, ctx: TraceCtx) {
        self.buf[self.next] = ctx;
        self.next = (self.next + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Retained spans, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &TraceCtx> {
        let start = (self.next + self.cap - self.len) % self.cap;
        (0..self.len).map(move |i| &self.buf[(start + i) % self.cap])
    }

    /// Retained spans, oldest first, as an owned vector (export only).
    pub fn to_vec(&self) -> Vec<TraceCtx> {
        self.iter().copied().collect()
    }

    /// The `n` slowest retained spans by total latency, slowest first;
    /// ties break on trace id so the dump is deterministic.
    pub fn slowest(&self, n: usize) -> Vec<TraceCtx> {
        slowest(&self.to_vec(), n)
    }
}

/// The `n` slowest spans by total latency, slowest first (deterministic:
/// ties break on trace id).
pub fn slowest(traces: &[TraceCtx], n: usize) -> Vec<TraceCtx> {
    let mut v = traces.to_vec();
    v.sort_by(|a, b| b.total_ns().cmp(&a.total_ns()).then(a.id.cmp(&b.id)));
    v.truncate(n);
    v
}

/// One span as a single JSON line (fixed key order, no trailing newline).
pub fn span_json(ctx: &TraceCtx) -> String {
    use std::fmt::Write;
    let st = ctx.stages();
    let mut s = String::with_capacity(256);
    let _ = write!(s, "{{\"trace_id\":{},\"total_ns\":{}", ctx.id, ctx.total_ns());
    for (i, name) in STAGE_KIND_NAMES.iter().enumerate() {
        let _ = write!(s, ",\"{name}_ns\":{}", st.ns[i]);
    }
    s.push_str(",\"stamps_ns\":[");
    for (i, v) in ctx.stamps.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{v}");
    }
    s.push_str("]}");
    s
}

/// Render spans as JSONL (one [`span_json`] line per span).
pub fn write_jsonl(traces: &[TraceCtx], out: &mut String) {
    for t in traces {
        out.push_str(&span_json(t));
        out.push('\n');
    }
}

/// Human-readable exemplar dump: the `n` slowest spans with their full
/// stage breakdowns, in milliseconds.
pub fn exemplar_table(traces: &[TraceCtx], n: usize) -> String {
    use std::fmt::Write;
    let picks = slowest(traces, n);
    let mut s = String::new();
    let _ = write!(s, "{:>16} {:>9}", "trace", "total");
    for name in STAGE_KIND_NAMES {
        let _ = write!(s, " {name:>9}");
    }
    s.push('\n');
    for t in &picks {
        let st = t.stages();
        let _ = write!(s, "{:>16x} {:>9.3}", t.id, t.total_ns() as f64 / 1e6);
        for v in st.ns {
            let _ = write!(s, " {:>9.3}", v as f64 / 1e6);
        }
        s.push('\n');
    }
    if picks.is_empty() {
        s.push_str("(no closed spans recorded)\n");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::framing::{Msg, Payload, Request, Response, MSG_HELLO};
    use crate::sim::clock::SimClock;

    fn closed_span() -> TraceCtx {
        // monotone stamps: mint=10, encode=30, send=35, gw=60, enqueue=100,
        // dequeue=400, pack=420, execute=520, reply=530, recv=600
        let mut c = TraceCtx::mint(0xfeed, 10);
        for (stage, ns) in
            [(STAGE_ENCODE, 30), (STAGE_SEND, 35), (STAGE_GW_FORWARD, 60), (STAGE_ENQUEUE, 100), (STAGE_DEQUEUE, 400), (STAGE_PACK, 420), (STAGE_EXECUTE, 520), (STAGE_REPLY, 530), (STAGE_RECV, 600)]
        {
            c.stamp(stage, ns);
        }
        c
    }

    fn body_of(m: &Msg) -> Vec<u8> {
        m.encode()[4..].to_vec()
    }

    #[test]
    fn wire_roundtrip_is_exact() {
        let c = closed_span();
        let mut w = Vec::new();
        c.write_wire(&mut w);
        assert_eq!(w.len(), TRACE_WIRE_BYTES);
        assert_eq!(w[0], TRACE_TAG);
        assert_eq!(TraceCtx::read_wire(&w).unwrap(), c);
    }

    #[test]
    fn read_wire_rejects_bad_sizes_and_tag() {
        let c = closed_span();
        let mut w = Vec::new();
        c.write_wire(&mut w);
        assert!(TraceCtx::read_wire(&w[..TRACE_WIRE_BYTES - 1]).is_err());
        let mut long = w.clone();
        long.push(0);
        assert!(TraceCtx::read_wire(&long).is_err());
        let mut forged = w.clone();
        forged[0] = TRACE_TAG.wrapping_add(1);
        assert!(TraceCtx::read_wire(&forged).is_err());
    }

    #[test]
    fn split_trailer_peels_the_canonical_body() {
        let msg = Msg::Response(Response { client: 7, id: 42, action: vec![0.5, -0.5] });
        let canonical = body_of(&msg);
        let ctx = closed_span();
        let mut body = canonical.clone();
        append_trailer(&mut body, &ctx);
        assert_eq!(body.len(), canonical.len() + TRACE_WIRE_BYTES);
        let (inner, got) = split_trailer(&body).unwrap();
        assert_eq!(inner, &canonical[..]);
        assert_eq!(got, ctx);
        // and the peeled body decodes as the original message
        assert_eq!(Msg::decode(inner).unwrap(), msg);
    }

    #[test]
    fn split_trailer_rejects_ineligible_short_and_forged() {
        // ineligible type (hello)
        let hello = [MSG_HELLO, 0, 0, 0, 0, 0, 0, 0, 0];
        let mut h = hello.to_vec();
        h.extend_from_slice(&[0u8; TRACE_WIRE_BYTES]);
        assert!(split_trailer(&h).is_err());
        // empty + too short
        assert!(split_trailer(&[]).is_err());
        assert!(split_trailer(&[crate::net::framing::MSG_RESPONSE; TRACE_WIRE_BYTES]).is_err());
        // forged tag
        let msg = Msg::Response(Response { client: 1, id: 2, action: vec![] });
        let mut body = body_of(&msg);
        let ctx = closed_span();
        append_trailer(&mut body, &ctx);
        let base = body.len() - TRACE_WIRE_BYTES;
        body[base] = 0xaa;
        assert!(split_trailer(&body).is_err());
    }

    #[test]
    fn stamp_body_tail_patches_exactly_one_stamp() {
        let msg = Msg::Request(Request {
            client: 3,
            id: 9,
            payload: Payload::RawRgba { x: 2, data: vec![1; 16] },
        });
        let mut body = body_of(&msg);
        let ctx = TraceCtx::mint(0xabcd, 5);
        append_trailer(&mut body, &ctx);
        assert!(stamp_body_tail(&mut body, STAGE_GW_FORWARD, 777));
        let (_, got) = split_trailer(&body).unwrap();
        let mut want = ctx;
        want.stamp(STAGE_GW_FORWARD, 777);
        assert_eq!(got, want);
        // refuses traceless, ineligible and short bodies, leaving bytes alone
        let mut plain = body_of(&msg); // 31 bytes: shorter than any trailer
        let before = plain.clone();
        assert!(!stamp_body_tail(&mut plain, STAGE_GW_FORWARD, 1));
        assert_eq!(plain, before);
        let mut tiny = vec![MSG_REQUEST_RAW; 4];
        assert!(!stamp_body_tail(&mut tiny, STAGE_GW_FORWARD, 1));
        let mut hello = vec![MSG_HELLO; TRACE_WIRE_BYTES + 20];
        assert!(!stamp_body_tail(&mut hello, STAGE_GW_FORWARD, 1));
    }

    #[test]
    fn append_trace_reseals_the_length_prefix() {
        let msg = Msg::Response(Response { client: 1, id: 2, action: vec![1.0] });
        let mut frame = msg.encode();
        let body_len = frame.len() - 4;
        let ctx = closed_span();
        append_trace(&mut frame, &ctx);
        let sealed = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
        assert_eq!(sealed, body_len + TRACE_WIRE_BYTES);
        assert_eq!(frame.len(), 4 + sealed);
        let (inner, got) = split_trailer(&frame[4..]).unwrap();
        assert_eq!(Msg::decode(inner).unwrap(), msg);
        assert_eq!(got, ctx);
        // and the frame-level stamp helper hits the same trailer
        assert!(stamp_frame_tail(&mut frame, STAGE_RECV, 999));
        let (_, got) = split_trailer(&frame[4..]).unwrap();
        assert_eq!(got.stamps[STAGE_RECV], 999);
    }

    #[test]
    fn stage_decomposition_matches_hand_math() {
        let c = closed_span();
        let st = c.stages();
        assert_eq!(st.ns, [20, 65, 300, 20, 100, 10, 70]);
        assert_eq!(st.total(), 590);
        assert_eq!(c.total_ns(), 590);
        assert_eq!(st.wire(), 135);
        assert_eq!(st.queue(), 300);
        assert_eq!(st.dominant(), Some("queue"));
        assert_eq!(StageNs::default().dominant(), None);
    }

    #[test]
    fn stage_accumulation_and_windowed_delta() {
        let mut acc = StageNs::default();
        acc.accumulate(&closed_span());
        acc.accumulate(&closed_span());
        assert_eq!(acc.total(), 2 * 590);
        let mut later = acc;
        later.accumulate(&closed_span());
        let win = later.delta(&acc);
        assert_eq!(win.ns, closed_span().stages().ns);
        // saturating on reset
        assert_eq!(acc.delta(&later).total(), 0);
    }

    #[test]
    fn ring_overwrites_oldest_and_never_grows() {
        let mut r = Ring::with_capacity(3);
        assert!(r.is_empty());
        for i in 0..5u64 {
            let mut c = TraceCtx::mint(i, i);
            c.stamp(STAGE_RECV, i + 10 * i);
            r.push(c);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.capacity(), 3);
        let ids: Vec<u64> = r.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![2, 3, 4]);
        // slowest(n): totals are 10*i − 0, so 4 then 3
        let top = r.slowest(2);
        assert_eq!(top.iter().map(|c| c.id).collect::<Vec<_>>(), vec![4, 3]);
    }

    #[test]
    fn virtual_ns_is_deterministic_and_monotone() {
        assert_eq!(virtual_ns(0.0), 0);
        assert_eq!(virtual_ns(1.5), 1_500_000_000);
        assert_eq!(virtual_ns(0.000_000_001), 1);
        assert!(virtual_ns(2.0) > virtual_ns(1.999_999_999));
    }

    #[test]
    fn clock_seam_stamps_are_monotone() {
        let sim = SimClock::new();
        let h = sim.handle();
        let a = now_ns(&h);
        sim.advance_secs(0.5);
        let b = now_ns(&h);
        assert!(b >= a + 499_000_000, "virtual advance must show up: {a} -> {b}");
    }

    #[test]
    fn jsonl_and_exemplar_table_are_stable() {
        let c = closed_span();
        let line = span_json(&c);
        assert!(line.starts_with("{\"trace_id\":65261,\"total_ns\":590,\"encode_ns\":20,"));
        assert!(line.contains("\"queue_ns\":300"));
        assert!(line.ends_with(",\"stamps_ns\":[10,30,35,60,100,400,420,520,530,600]}"));
        let mut out = String::new();
        write_jsonl(&[c, c], &mut out);
        assert_eq!(out.lines().count(), 2);
        let table = exemplar_table(&[c], 5);
        assert!(table.contains("trace"));
        assert!(table.contains("wire_up"));
        assert!(table.contains("feed")); // hex id
        assert!(exemplar_table(&[], 5).contains("no closed spans"));
    }

    #[test]
    fn trailer_boundary_prefix_decodes_as_the_traceless_twin() {
        // The one structural consequence of an optional trailer: cutting
        // exactly TRACE_WIRE_BYTES off a traced frame yields its valid
        // traceless twin. Benign — dropping a trailer only loses
        // observability — and pinned here so it stays a *single* boundary:
        // every other strict prefix must still fail to decode.
        let msg = Msg::Request(Request {
            client: 1,
            id: 2,
            payload: Payload::Features { c: 1, h: 2, w: 2, scale: 0.5, data: vec![9; 4] },
        });
        let mut body = body_of(&msg);
        append_trailer(&mut body, &TraceCtx::mint(1, 1));
        let cut = body.len() - TRACE_WIRE_BYTES;
        for n in 1..body.len() {
            let prefix = &body[..n];
            // a trace-negotiated receiver always splits then decodes; that
            // composed path must reject EVERY strict prefix (at the cut the
            // split itself fails: the twin is too short to hold a trailer)
            let traced = split_trailer(prefix).and_then(|(inner, _)| Msg::decode(inner));
            assert!(traced.is_err(), "traced receiver must reject a {n}-byte prefix");
            // a traceless receiver sees the twin at exactly the cut
            if n == cut {
                assert_eq!(Msg::decode(prefix).unwrap(), msg);
            } else {
                assert!(Msg::decode(prefix).is_err(), "prefix of {n} bytes must not decode");
            }
        }
    }
}

//! Client fleet: simulated edge devices driving the coordinator.
//!
//! Each client runs the real Pendulum environment with the paper's
//! render-100 → crop-84 pipeline and, in split mode, executes the real
//! MiniConv encoder through the **shader interpreter** (the deployment
//! path: fragment-shader passes, not XLA). The simulated device model
//! supplies the on-device encode time j; the client sleeps out the
//! remainder so wall-clock decision latency reflects the modelled device.
//!
//! Decision latency (paper §4.3) = observation available → action received.

use std::io::Write;
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::codec::{self, CodecId, Encoder, RateConfig, RateController, CODEC_DELTA};
use crate::device::{Device, DeviceSpec, ExecPath, FrameCost};
use crate::envs::{CropMode, Env, Pendulum, PixelPipeline};
use crate::net::framing::{
    ExperienceFrame, FeatureFrame, Hello, Msg, Payload, Request, CAP_EXPERIENCE, CAP_TRACE,
    ERR_OVERLOADED, EXP_DONE, EXP_EP_START, EXP_HAS_REWARD, EXP_TERMINATED,
};
use crate::net::limits::backoff_delay;
use crate::net::shaped::ShapedWriter;
use crate::net::tcp::{read_msg, read_raw_frame, write_frame, write_msg};
use crate::trace::{self, TraceCtx};
use crate::rl::native::{episode_rng, normalize_pendulum_obs};
use crate::runtime::Manifest;
use crate::sim::clock::ClockHandle;
use crate::shader::{compiled_from_manifest, CompiledPipeline, TextureFormat};
use crate::tensor::Chw;
use crate::util::rng::Rng;
use crate::util::stats::Samples;

use super::router::Route;

#[derive(Clone)]
pub struct ClientConfig {
    pub mode: Route,
    pub arch: String,
    pub decisions: usize,
    /// fixed decision rate (Hz); None = closed loop (next decision as soon
    /// as the previous action arrives)
    pub rate_hz: Option<f64>,
    /// upstream bandwidth shaping in bits/s; None = unshaped
    pub shape_bps: Option<f64>,
    /// simulated device for on-device encode time; None = no extra delay
    pub device: Option<DeviceSpec>,
    pub artifact_dir: PathBuf,
    pub seed: u64,
    /// server-only mode: pin the observation side length instead of reading
    /// it from the artifact manifest, letting fleets run artifact-free
    /// against Sim-backend coordinators (ignored in split mode, which needs
    /// the manifest for the shader pipeline anyway)
    pub obs_x: Option<usize>,
    /// feature-frame codec for the split route, negotiated in the Hello
    /// handshake (raw-route clients ignore it; if the server ack declines
    /// the codec the session falls back to the flat v1 format)
    pub codec: CodecId,
    /// rate-controller tuning for the delta codec (quantisation ladder,
    /// latency target, keyframe cadence)
    pub rate: RateConfig,
    /// time source for pacing, shaping, and latency stamps (the clock
    /// seam, DESIGN.md §6); defaults to the wall clock. Keep it wall for
    /// a live client — socket reads still block in real time — and use
    /// the `sim::scenario` runner for fully virtual-time clients; the
    /// shaped-link property tests drive `ShapedWriter` alone under a
    /// `SimClock` through this same seam.
    pub clock: ClockHandle,
    /// per-decision distributed tracing (DESIGN.md §12): request
    /// [`CAP_TRACE`] in the Hello and, when the server grants it, mint a
    /// span per decision, stamp the client hops (mint/encode/send/recv),
    /// carry it on the wire, and keep the closed spans in
    /// [`ClientReport::traces`]
    pub trace: bool,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            mode: Route::Split,
            arch: "miniconv4".into(),
            decisions: 100,
            rate_hz: None,
            shape_bps: None,
            device: None,
            artifact_dir: crate::runtime::default_artifact_dir(),
            seed: 0,
            obs_x: None,
            codec: CodecId::Flat,
            rate: RateConfig::default(),
            clock: ClockHandle::wall(),
            trace: false,
        }
    }
}

#[derive(Debug, Default)]
pub struct ClientReport {
    /// decision latencies, seconds
    pub latencies: Samples,
    /// on-device encode times (split mode), seconds
    pub encode_times: Samples,
    pub decisions: usize,
    pub errors: usize,
    /// wall time of the whole run, seconds
    pub elapsed: f64,
    /// total request bytes put on the wire
    pub bytes_sent: u64,
    /// codec keyframes sent (delta codec only)
    pub keyframes: u64,
    /// codec delta frames sent
    pub deltas: u64,
    /// server re-key demands observed (chain breaks it could not decode)
    pub need_keyframes: u64,
    /// requests explicitly shed with an [`ERR_OVERLOADED`] frame (the
    /// client backed off with jittered retry delays, DESIGN.md §9)
    pub overloaded: u64,
    /// rate controller's final quantisation ceiling (0 = flat codec)
    pub final_qmax: u8,
    /// topology epoch stamped into the hello ack (0 = server not
    /// fleet-fronted, or the ack was never read — raw/flat sessions use a
    /// fire-and-forget handshake)
    pub topology_epoch: u64,
    /// closed per-decision spans (trace-negotiated sessions only; bounded
    /// by the client's flight-recorder ring, most recent decisions)
    pub traces: Vec<TraceCtx>,
}

impl ClientReport {
    pub fn achieved_hz(&self) -> f64 {
        if self.elapsed > 0.0 {
            self.decisions as f64 / self.elapsed
        } else {
            0.0
        }
    }
}

enum Sender_ {
    Plain(TcpStream),
    Shaped(ShapedWriter<TcpStream>),
}

impl Sender_ {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        match self {
            Sender_::Plain(s) => write_msg(s, msg),
            Sender_::Shaped(s) => write_msg(s, msg),
        }
    }

    fn send_frame(&mut self, frame: &[u8]) -> Result<()> {
        match self {
            Sender_::Plain(s) => write_frame(s, frame),
            Sender_::Shaped(s) => write_frame(s, frame),
        }
    }
}

/// Client-side read: permissive framing (the client trusts its server) but
/// trace-aware — on a trace-negotiated session every eligible frame ends in
/// a trailer to peel before the canonical decode (DESIGN.md §12).
fn read_reply(
    recv: &mut TcpStream,
    buf: &mut Vec<u8>,
    traced: bool,
) -> Result<Option<(Msg, Option<TraceCtx>)>> {
    if !read_raw_frame(recv, buf)? {
        return Ok(None);
    }
    if traced && !buf.is_empty() && trace::trace_eligible(buf[0]) {
        let (inner, ctx) = trace::split_trailer(buf)?;
        return Ok(Some((Msg::decode(inner)?, Some(ctx))));
    }
    Ok(Some((Msg::decode(buf)?, None)))
}

/// Run one client against the server at `addr`.
pub fn run_client(
    addr: std::net::SocketAddr,
    client_id: u32,
    cfg: &ClientConfig,
) -> Result<ClientReport> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_nodelay(true).ok();
    let mut recv = stream.try_clone()?;
    let mut send = match cfg.shape_bps {
        Some(bps) => Sender_::Shaped(ShapedWriter::with_clock(stream, bps, cfg.clock.clone())),
        None => Sender_::Plain(stream),
    };

    // split mode: the real compiled shader encoder over manifest params
    // (the legacy interpreter stays as the test oracle). Server-only mode
    // with a pinned obs_x never touches the manifest, so Sim-backend
    // fleets run artifact-free.
    type SplitSetup = (Option<CompiledPipeline>, usize, Option<FrameCost>, usize);
    let (mut shader, feat_k, cost, serve_x): SplitSetup =
        if cfg.mode == Route::Split {
            let manifest = Manifest::load(&cfg.artifact_dir)?;
            let serve_x = manifest.serve_x;
            let (serve_meta, _) = manifest
                .encoders
                .get(&cfg.arch)
                .ok_or_else(|| anyhow::anyhow!("unknown arch {}", cfg.arch))?;
            let mut pipe = compiled_from_manifest(
                &manifest,
                &cfg.arch,
                serve_meta,
                serve_x,
                &format!("serve_enc_{}", cfg.arch),
                TextureFormat::Float,
            )?;
            // parallelise independent passes up to the modelled device's cores
            if let Some(spec) = &cfg.device {
                pipe.set_threads(spec.cpu_cores);
            }
            let cost = FrameCost::from_plan(pipe.plan());
            (Some(pipe), serve_meta.feat_shape[0], Some(cost), serve_x)
        } else {
            let serve_x = match cfg.obs_x {
                Some(x) => x,
                None => Manifest::load(&cfg.artifact_dir)?.serve_x,
            };
            (None, 0, None, serve_x)
        };
    let mut device = cfg.device.clone().map(|spec| Device::new(spec, cfg.seed));

    // delta-codec state for the split route: encoder + closed-loop rate
    // controller. Dropped to `None` (flat v1 fallback) if the server's
    // hello ack declines the codec.
    let mut delta: Option<(Encoder, RateController)> = (cfg.mode == Route::Split
        && cfg.codec == CodecId::Delta)
        .then(|| (Encoder::new(), RateController::new(cfg.rate.clone())));

    send.send(&Msg::Hello(Hello {
        client: client_id,
        split: cfg.mode == Route::Split,
        codec: if cfg.mode == Route::Split { cfg.codec.wire_id() } else { 0 },
        caps: if cfg.trace { CAP_TRACE } else { 0 },
        shard: None,
        epoch: None,
    }))?;

    // negotiation barrier: the first frame's format depends on the
    // server's verdict, so a delta client blocks on the hello ack before
    // encoding anything, and a trace-requesting client blocks for the
    // capability verdict — attaching a trailer the server never granted
    // would be an undecodable frame (flat/raw untraced clients keep the
    // fire-and-forget handshake — their format needs no agreement)
    let mut topology_epoch = 0u64;
    let mut traced = false;
    if delta.is_some() || cfg.trace {
        loop {
            match read_msg(&mut recv)? {
                Some(Msg::Hello(ack)) => {
                    if ack.codec != CODEC_DELTA {
                        // server declined: fall back to the flat v1 format
                        delta = None;
                    }
                    traced = ack.caps & CAP_TRACE != 0;
                    // a fleet-fronted ack carries the topology epoch the
                    // placement was computed under; reconnects echo it so
                    // stale re-routes are refused server-side
                    topology_epoch = ack.epoch.unwrap_or(0);
                    break;
                }
                Some(_) => continue, // stray traffic on a fresh connection
                None => anyhow::bail!("server closed during codec negotiation"),
            }
        }
    }

    let mut env = Pendulum::new();
    let mut rng = Rng::new(cfg.seed.wrapping_mul(0x9E37).wrapping_add(client_id as u64));
    // the backoff jitter draws from its own stream so an overload event
    // never perturbs the environment's episode determinism
    let mut backoff_rng = Rng::new(cfg.seed ^ 0xBACC0FF ^ client_id as u64);
    let mut overload_attempts = 0u32;
    env.reset(&mut rng);
    let mut pipeline = PixelPipeline::new(100, serve_x, CropMode::Center);
    pipeline.observe(&env, &mut rng);

    let mut report = ClientReport::default();
    let t_run = cfg.clock.now();
    let tick = cfg.rate_hz.map(|hz| Duration::from_secs_f64(1.0 / hz));
    let mut next_tick = cfg.clock.now();
    // per-frame scratch reused across decisions (steady-state: no growth)
    let mut feat = Chw::zeros(1, 1, 1);
    let mut flat: Vec<f32> = Vec::new();
    let mut qbuf: Vec<u8> = Vec::new();
    // trace-session scratch: pooled request frame, pooled read buffer, and
    // the client's flight-recorder ring of closed spans
    let mut tframe: Vec<u8> = Vec::new();
    let mut rbuf: Vec<u8> = Vec::new();
    let mut ring = trace::Ring::with_capacity(1024);

    for i in 0..cfg.decisions {
        if let Some(t) = tick {
            let now = cfg.clock.now();
            if next_tick > now {
                cfg.clock.sleep(next_tick - now);
            }
            next_tick += t;
        }

        // observation is now available: the decision clock starts
        let t0 = cfg.clock.now();
        let payload = match (&mut shader, &mut device) {
            (Some(pipe), dev) => {
                // on-device encode (real compiled-shader execution over
                // reused scratch; single-thread runs are allocation-free,
                // multi-pass layers at threads>1 pay only the scoped spawns)
                let enc_t0 = cfg.clock.now();
                pipe.run_into(&pipeline.obs_chw(), &mut feat)?;
                let real_encode = cfg.clock.now().duration_since(enc_t0).as_secs_f64();
                // pad out to the simulated device's encode time
                let sim_j = dev
                    .as_mut()
                    .map(|d| d.encode_frame(cost.as_ref().unwrap(), ExecPath::Gpu).duration)
                    .unwrap_or(real_encode);
                if sim_j > real_encode {
                    cfg.clock.sleep(Duration::from_secs_f64(sim_j - real_encode));
                }
                report.encode_times.push(real_encode.max(sim_j));
                // transmit only the K-channel feature map, quantised to u8
                // (the flatten buffer is reused; the wire buffer must be
                // owned by the message)
                let (c, h, w) = (feat_k, feat.h, feat.w);
                flat.clear();
                flat.reserve(c * h * w);
                for ch in 0..c {
                    for y in 0..h {
                        for x in 0..w {
                            flat.push(feat.at(ch, y, x));
                        }
                    }
                }
                match &mut delta {
                    Some((encoder, rate)) => {
                        // negotiated codec: quantise at the controller's
                        // ceiling, delta-encode against the previous frame
                        // (keyframe when the controller demands one), ship
                        // the packed payload with the chain header
                        if rate.keyframe_due() {
                            encoder.force_keyframe();
                        }
                        let qmax = rate.qmax();
                        let scale = codec::quantize_into(&flat, qmax, &mut qbuf);
                        let mut data = Vec::new();
                        let (flags, seq) = encoder.encode_into(&qbuf, &mut data);
                        let key = flags & codec::FLAG_KEYFRAME != 0;
                        rate.frame_sent(key);
                        if key {
                            report.keyframes += 1;
                        } else {
                            report.deltas += 1;
                        }
                        Payload::FeaturesV2(FeatureFrame {
                            c: c as u16,
                            h: h as u16,
                            w: w as u16,
                            codec: CODEC_DELTA,
                            flags,
                            qmax,
                            seq,
                            scale,
                            data,
                        })
                    }
                    None => {
                        let (scale, q) = crate::net::quantize_features(&flat);
                        Payload::Features { c: c as u16, h: h as u16, w: w as u16, scale, data: q }
                    }
                }
            }
            (None, _) => Payload::RawRgba { x: serve_x as u16, data: pipeline.rgba_bytes() },
        };
        let wire_b = payload.wire_bytes();
        report.bytes_sent += wire_b as u64;
        let msg = Msg::Request(Request { client: client_id, id: i as u64, payload });
        if traced {
            // span id: client in the high half, decision index in the low,
            // unique across a fleet run. Stamps ride the wire, so the
            // send-side hops are stamped before the trailer is appended.
            let mut t = TraceCtx::mint(
                ((client_id as u64) << 32) | i as u64,
                trace::ns_since_epoch(t0),
            );
            t.stamp(trace::STAGE_ENCODE, trace::now_ns(&cfg.clock));
            msg.encode_into(&mut tframe);
            t.stamp(trace::STAGE_SEND, trace::now_ns(&cfg.clock));
            trace::append_trace(&mut tframe, &t);
            send.send_frame(&tframe)?;
        } else {
            send.send(&msg)?;
        }

        // await our action (plus the echoed span on traced sessions)
        let (action, rctx) = loop {
            match read_reply(&mut recv, &mut rbuf, traced)? {
                Some((Msg::Response(r), ctx)) if r.id == i as u64 => break (r.action, ctx),
                Some((Msg::ResponseV2(r), ctx)) if r.id == i as u64 => {
                    // the codec feedback that closes the rate-control loop
                    if let Some((encoder, rate)) = &mut delta {
                        let lat = cfg.clock.now().duration_since(t0).as_secs_f64();
                        rate.on_ack(wire_b, lat, r.queue_wait_us as f64 * 1e-6);
                        if r.need_keyframe() {
                            rate.on_loss();
                            encoder.force_keyframe();
                            report.need_keyframes += 1;
                        }
                    }
                    break (r.action, ctx);
                }
                Some((Msg::Error(e), _)) if e.code == ERR_OVERLOADED => {
                    // explicit load-shed (DESIGN.md §9): the request was
                    // refused outright, so there is no response to wait
                    // for. Back off with full jitter — decorrelating a
                    // thundering herd of retries — and take the zero
                    // action for this decision.
                    report.overloaded += 1;
                    overload_attempts += 1;
                    let d = backoff_delay(0.010, overload_attempts, 0.5, &mut backoff_rng);
                    cfg.clock.sleep(Duration::from_secs_f64(d));
                    break (vec![], None);
                }
                // the codec verdict was consumed at the negotiation
                // barrier; a late/duplicate ack must not renegotiate a
                // stream that is already flowing
                Some((Msg::Hello(_), _)) => continue,
                Some(_) => continue, // stale response
                None => anyhow::bail!("server closed connection"),
            }
        };
        // close the span: the action is back where the pixels started
        if let Some(mut t) = rctx {
            t.stamp(trace::STAGE_RECV, trace::now_ns(&cfg.clock));
            ring.push(t);
        }
        if action.is_empty() {
            // explicit server rejection (back-pressure): count and move on
            report.errors += 1;
        } else {
            overload_attempts = 0; // served again: reset the backoff ladder
            report
                .latencies
                .push(cfg.clock.now().duration_since(t0).as_secs_f64());
            report.decisions += 1;
        }

        // act in the environment and produce the next observation (zero
        // action on rejection — the env still advances in real time)
        let a: Vec<f64> = if action.is_empty() {
            vec![0.0; env.action_dim()]
        } else {
            action.iter().map(|&v| v as f64).collect()
        };
        let out = env.step(&a);
        if out.done() {
            env.reset(&mut rng);
            pipeline.clear();
        }
        pipeline.observe(&env, &mut rng);
    }
    report.elapsed = cfg.clock.now().duration_since(t_run).as_secs_f64();
    report.traces = ring.to_vec();
    report.topology_epoch = topology_epoch;
    report.final_qmax = delta.as_ref().map(|(_, rate)| rate.qmax()).unwrap_or(0);
    if let Sender_::Plain(ref mut s) = send {
        let _ = s.flush();
    }
    Ok(report)
}

/// One on-policy learning client (DESIGN.md §8): drives Pendulum locally
/// and streams experience frames — codec-compressed observations plus
/// the previous action's reward/done — to a learn-capable server, which
/// acts, trains, and versions the policy. Capability is negotiated in
/// the `Hello` handshake; a cleared `CAP_EXPERIENCE` bit (or an explicit
/// error frame mid-run) downgrades the session to inference-only frames.
#[derive(Debug, Clone)]
pub struct LearnClientConfig {
    /// episodes to complete before the final flush frame
    pub episodes: usize,
    /// per-episode environment streams (`episode_rng(seed, ep)`) — client
    /// 0 at seed s replays the offline `rl::NativeTrainer` at seed s
    pub seed: u64,
    /// staleness bound the client re-checks on every applied action
    pub max_lag: u64,
}

impl Default for LearnClientConfig {
    fn default() -> Self {
        LearnClientConfig { episodes: 10, seed: 0, max_lag: 4 }
    }
}

#[derive(Debug, Default)]
pub struct LearnClientReport {
    /// per-episode undiscounted returns, in completion order
    pub returns: Vec<f64>,
    pub experience_frames: u64,
    pub bytes_sent: u64,
    /// actions refused by the staleness gate (client re-kicked the frame)
    pub stale_rejections: u64,
    /// actions applied whose version lag exceeded `max_lag` (must be 0)
    pub applied_stale: u64,
    /// server re-key demands observed
    pub need_keyframes: u64,
    /// highest policy version observed in response stamps
    pub latest_version: u64,
    /// the session was downgraded to inference-only frames
    pub fallback: bool,
    /// requests explicitly shed with an [`ERR_OVERLOADED`] frame
    pub overloaded: u64,
    pub errors: usize,
    /// topology epoch stamped into the hello ack (0 = not fleet-fronted)
    pub topology_epoch: u64,
}

/// Run one learning client against the server at `addr`.
pub fn run_learn_client(
    addr: std::net::SocketAddr,
    client_id: u32,
    cfg: &LearnClientConfig,
) -> Result<LearnClientReport> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_nodelay(true).ok();
    let mut recv = stream.try_clone()?;
    let mut send = stream;
    let mut report = LearnClientReport::default();

    write_msg(
        &mut send,
        &Msg::Hello(Hello {
            client: client_id,
            split: true,
            codec: CODEC_DELTA,
            caps: CAP_EXPERIENCE,
            shard: None,
            epoch: None,
        }),
    )?;
    // negotiation barrier: both the codec verdict and the capability mask
    // decide the first frame's format
    let mut experience = loop {
        match read_msg(&mut recv)? {
            Some(Msg::Hello(ack)) => {
                anyhow::ensure!(ack.codec == CODEC_DELTA, "server declined the delta codec");
                report.topology_epoch = ack.epoch.unwrap_or(0);
                break ack.caps & CAP_EXPERIENCE != 0;
            }
            Some(_) => continue, // stray traffic on a fresh connection
            None => anyhow::bail!("server closed during capability negotiation"),
        }
    };
    report.fallback = !experience;

    let mut env = Pendulum::new();
    let mut env_rng = episode_rng(cfg.seed, 0);
    env.reset(&mut env_rng);
    let max_a = env.max_action();
    // jittered-backoff state for explicit load-shed frames; a separate
    // stream so overload never perturbs the episode determinism
    let mut backoff_rng = Rng::new(cfg.seed ^ 0xBACC0FF ^ client_id as u64);
    let mut overload_attempts = 0u32;
    let mut encoder = Encoder::new();
    let mut obs = vec![0.0f32; 3];
    let mut qbuf: Vec<u8> = Vec::new();

    let (mut ep, mut step) = (0u32, 0u32);
    let mut ep_return = 0.0f64;
    // reward/done of the previous action, carried by the next frame
    let (mut frame_flags, mut pending_reward) = (EXP_EP_START, 0.0f32);
    let mut id = 0u64;

    loop {
        if !experience && ep as usize >= cfg.episodes {
            // inference-only sessions have nothing to flush server-side
            break;
        }
        // frame (ep, step): the observation at this step
        normalize_pendulum_obs(&env.state(), &mut obs);
        let scale = codec::quantize_into(&obs, 255, &mut qbuf);
        let mut data = Vec::new();
        let (cflags, seq) = encoder.encode_into(&qbuf, &mut data);
        let feat = FeatureFrame {
            c: 3,
            h: 1,
            w: 1,
            codec: CODEC_DELTA,
            flags: cflags,
            qmax: 255,
            seq,
            scale,
            data,
        };
        let payload = if experience {
            Payload::Experience(ExperienceFrame {
                feat,
                ep,
                step,
                flags: frame_flags,
                reward: pending_reward,
            })
        } else {
            Payload::FeaturesV2(feat)
        };
        report.bytes_sent += payload.wire_bytes() as u64;
        if experience {
            report.experience_frames += 1;
        }
        write_msg(&mut send, &Msg::Request(Request { client: client_id, id, payload }))?;
        let sent_id = id;
        id += 1;

        // await the verdict for this frame
        let action = loop {
            match read_msg(&mut recv)? {
                Some(Msg::ResponseLearn(r)) if r.id == sent_id => {
                    report.latest_version = report.latest_version.max(r.latest_version);
                    if r.need_keyframe() {
                        encoder.force_keyframe();
                        report.need_keyframes += 1;
                        break None; // resend the same (ep, step)
                    }
                    if r.stale() {
                        // the gate refused the acting version: re-kick the
                        // same decision point, never step on a stale action
                        report.stale_rejections += 1;
                        break None;
                    }
                    if r.latest_version.saturating_sub(r.acting_version) > cfg.max_lag {
                        report.applied_stale += 1;
                    }
                    break Some(r.action);
                }
                Some(Msg::Response(r)) if r.id == sent_id => break Some(r.action),
                Some(Msg::ResponseV2(r)) if r.id == sent_id => {
                    if r.need_keyframe() {
                        encoder.force_keyframe();
                        report.need_keyframes += 1;
                        break None;
                    }
                    break Some(r.action);
                }
                Some(Msg::Error(e)) if e.code == ERR_OVERLOADED => {
                    // load-shed, not a capability verdict: keep the
                    // session mode, back off with full jitter, re-key
                    // (the shed frame never reached the decoder, so the
                    // delta chain must restart) and resend this (ep, step)
                    debug_assert_eq!(e.client, client_id);
                    report.overloaded += 1;
                    report.errors += 1;
                    overload_attempts += 1;
                    let d = backoff_delay(0.010, overload_attempts, 0.5, &mut backoff_rng);
                    std::thread::sleep(Duration::from_secs_f64(d));
                    encoder.force_keyframe();
                    break None;
                }
                Some(Msg::Error(e)) => {
                    // explicit capability rejection: downgrade to
                    // inference-only and resend this observation
                    debug_assert_eq!(e.client, client_id);
                    experience = false;
                    report.fallback = true;
                    report.errors += 1;
                    encoder.force_keyframe();
                    break None;
                }
                Some(_) => continue, // stale traffic
                None => anyhow::bail!("server closed connection"),
            }
        };
        let Some(action) = action else { continue };
        overload_attempts = 0; // served again: reset the backoff ladder

        if experience && ep as usize >= cfg.episodes {
            // that was the flush frame: the final transition's reward is
            // consumed server-side; the extra action is never applied
            break;
        }
        if action.is_empty() {
            report.errors += 1;
        }
        let a64: Vec<f64> = if action.is_empty() {
            vec![0.0; env.action_dim()]
        } else {
            action.iter().map(|&v| (v as f64).clamp(-max_a, max_a)).collect()
        };
        let out = env.step(&a64);
        ep_return += out.reward;
        pending_reward = out.reward as f32;
        frame_flags = EXP_HAS_REWARD;
        if out.done() {
            report.returns.push(ep_return);
            ep_return = 0.0;
            ep += 1;
            step = 0;
            frame_flags |= EXP_DONE | EXP_EP_START;
            if out.terminated {
                frame_flags |= EXP_TERMINATED;
            }
            let mut r = episode_rng(cfg.seed, ep as u64);
            env.reset(&mut r);
        } else {
            step += 1;
        }
    }
    Ok(report)
}

/// Run `n` clients concurrently; returns per-client reports.
pub fn run_fleet(
    addr: std::net::SocketAddr,
    n: usize,
    cfg: &ClientConfig,
) -> Result<Vec<ClientReport>> {
    let mut handles = Vec::new();
    for i in 0..n {
        let mut c = cfg.clone();
        c.seed = cfg.seed.wrapping_add(i as u64 * 1000 + 1);
        let h = std::thread::Builder::new()
            .name(format!("mc-client-{i}"))
            .spawn(move || run_client(addr, i as u32, &c))?;
        handles.push(h);
    }
    handles
        .into_iter()
        .map(|h| h.join().map_err(|_| anyhow::anyhow!("client panicked"))?)
        .collect()
}

/// Merge per-client latency samples into one distribution (seconds).
pub fn merged_latencies(reports: &[ClientReport]) -> Samples {
    let mut all = Samples::new();
    for r in reports {
        for &v in r.latencies.values() {
            all.push(v);
        }
    }
    all
}

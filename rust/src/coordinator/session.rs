//! Per-client server-side session state.
//!
//! The server-only pipeline receives one RGBA frame per decision, but the
//! policy consumes a 3-frame stack; the session manager keeps each client's
//! frame history and materialises the 9-channel observation (repeating the
//! first frame after connect, matching the training-time FrameStack reset
//! semantics).

use std::collections::HashMap;

use anyhow::{ensure, Result};

/// One client's stacking state: up to 3 most-recent frames as normalised
/// 3-channel planes, held in a fixed ring so steady-state ingest reuses
/// the same three buffers forever (no per-request allocation, no
/// shift-down of older frames).
#[derive(Debug, Default)]
struct ClientState {
    /// ring of the 3 most-recent planes, each 3*x*x floats (CHW)
    ring: [Vec<f32>; 3],
    /// frames ingested since the last reset, saturating at 3
    count: usize,
    /// ring slot holding the newest frame
    newest: usize,
    x: usize,
}

#[derive(Debug, Default)]
pub struct SessionManager {
    clients: HashMap<u32, ClientState>,
}

impl SessionManager {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn n_clients(&self) -> usize {
        self.clients.len()
    }

    pub fn disconnect(&mut self, client: u32) {
        self.clients.remove(&client);
    }

    /// Ingest an RGBA frame (4·x² bytes), writing the stacked 9×x×x
    /// observation (oldest→newest) directly into `out` — a batch-matrix
    /// row on the serving hot path. Steady-state calls touch the heap
    /// only until the client's ring buffers are warm.
    pub fn ingest_rgba_into(
        &mut self,
        client: u32,
        x: usize,
        rgba: &[u8],
        out: &mut [f32],
    ) -> Result<()> {
        ensure!(rgba.len() == 4 * x * x, "rgba size {} != {}", rgba.len(), 4 * x * x);
        ensure!(out.len() == 9 * x * x, "obs slice {} != {}", out.len(), 9 * x * x);
        let st = self.clients.entry(client).or_default();
        if st.x != x {
            // resolution change (or first frame): reset the stack
            st.count = 0;
            st.newest = 0;
            st.x = x;
        }
        // RGBA HWC u8 -> RGB CHW f32/255 (alpha dropped), into the ring
        // slot after the newest (the expiring oldest slot once full)
        let slot = if st.count == 0 { 0 } else { (st.newest + 1) % 3 };
        let plane = &mut st.ring[slot];
        if plane.len() != 3 * x * x {
            // first use of this slot (or a resolution change): size it once;
            // the pixel loop below overwrites every element, so a warm plane
            // needs no zero-fill
            plane.clear();
            plane.resize(3 * x * x, 0.0);
        }
        for y in 0..x {
            for xx in 0..x {
                let i = (y * x + xx) * 4;
                for c in 0..3 {
                    plane[c * x * x + y * x + xx] = rgba[i + c] as f32 / 255.0;
                }
            }
        }
        st.newest = slot;
        st.count = (st.count + 1).min(3);
        // stack oldest→newest; while count < 3 the first frame repeats,
        // matching the training-time FrameStack reset semantics
        let n = 3 * x * x;
        if n > 0 {
            for (j, dst) in out.chunks_mut(n).enumerate() {
                let back = (2 - j).min(st.count - 1); // frames back from newest
                let slot = (st.newest + 3 - back) % 3;
                dst.copy_from_slice(&st.ring[slot]);
            }
        }
        Ok(())
    }

    /// Ingest an RGBA frame and return the stacked observation
    /// (allocating wrapper over [`SessionManager::ingest_rgba_into`]).
    pub fn ingest_rgba(&mut self, client: u32, x: usize, rgba: &[u8]) -> Result<Vec<f32>> {
        let mut obs = vec![0.0f32; 9 * x * x];
        self.ingest_rgba_into(client, x, rgba, &mut obs)?;
        Ok(obs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(x: usize, v: u8) -> Vec<u8> {
        let mut f = vec![v; 4 * x * x];
        // opaque alpha
        for a in f.iter_mut().skip(3).step_by(4) {
            *a = 255;
        }
        f
    }

    #[test]
    fn first_frame_repeats_three_times() {
        let mut s = SessionManager::new();
        let obs = s.ingest_rgba(1, 4, &frame(4, 100)).unwrap();
        assert_eq!(obs.len(), 9 * 16);
        let n = 3 * 16;
        assert_eq!(&obs[0..n], &obs[n..2 * n]);
        assert_eq!(&obs[n..2 * n], &obs[2 * n..3 * n]);
        assert!((obs[0] - 100.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn stack_slides() {
        let mut s = SessionManager::new();
        s.ingest_rgba(1, 4, &frame(4, 10)).unwrap();
        s.ingest_rgba(1, 4, &frame(4, 20)).unwrap();
        let obs = s.ingest_rgba(1, 4, &frame(4, 30)).unwrap();
        let n = 3 * 16;
        assert!((obs[0] - 10.0 / 255.0).abs() < 1e-6); // oldest first
        assert!((obs[n] - 20.0 / 255.0).abs() < 1e-6);
        assert!((obs[2 * n] - 30.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn clients_are_isolated() {
        let mut s = SessionManager::new();
        s.ingest_rgba(1, 4, &frame(4, 10)).unwrap();
        let obs2 = s.ingest_rgba(2, 4, &frame(4, 99)).unwrap();
        assert!((obs2[0] - 99.0 / 255.0).abs() < 1e-6);
        assert_eq!(s.n_clients(), 2);
        s.disconnect(1);
        assert_eq!(s.n_clients(), 1);
    }

    #[test]
    fn alpha_is_dropped() {
        let mut s = SessionManager::new();
        let mut f = frame(2, 0);
        f[3] = 77; // alpha byte should not appear anywhere
        let obs = s.ingest_rgba(1, 2, &f).unwrap();
        assert!(obs.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn wrong_size_rejected() {
        let mut s = SessionManager::new();
        assert!(s.ingest_rgba(1, 4, &[0; 10]).is_err());
    }

    #[test]
    fn into_variant_matches_wrapper_across_sequences() {
        // drive two managers through the same frame stream (including a
        // resolution change and interleaved clients); the in-place variant
        // must produce the wrapper's observations exactly
        let mut a = SessionManager::new();
        let mut b = SessionManager::new();
        let stream: [(u32, usize, u8); 6] =
            [(1, 4, 10), (2, 4, 99), (1, 4, 20), (1, 2, 50), (1, 2, 60), (2, 4, 7)];
        for (client, x, v) in stream {
            let f = frame(x, v);
            let want = a.ingest_rgba(client, x, &f).unwrap();
            let mut got = vec![f32::NAN; 9 * x * x];
            b.ingest_rgba_into(client, x, &f, &mut got).unwrap();
            assert_eq!(want, got, "client {client} x {x} v {v}");
        }
    }

    #[test]
    fn into_variant_rejects_wrong_out_len() {
        let mut s = SessionManager::new();
        let mut out = vec![0.0f32; 9 * 16 - 1];
        assert!(s.ingest_rgba_into(1, 4, &frame(4, 1), &mut out).is_err());
    }

    #[test]
    fn zero_sized_frame_is_a_no_op_observation() {
        let mut s = SessionManager::new();
        let obs = s.ingest_rgba(3, 0, &[]).unwrap();
        assert!(obs.is_empty());
    }

    #[test]
    fn resolution_change_resets_stack() {
        let mut s = SessionManager::new();
        s.ingest_rgba(1, 4, &frame(4, 10)).unwrap();
        let obs = s.ingest_rgba(1, 2, &frame(2, 50)).unwrap();
        assert_eq!(obs.len(), 9 * 4);
        let n = 3 * 4;
        assert_eq!(&obs[0..n], &obs[n..2 * n]); // fresh stack
    }
}

//! Per-client server-side session state.
//!
//! The server-only pipeline receives one RGBA frame per decision, but the
//! policy consumes a 3-frame stack; the session manager keeps each client's
//! frame history and materialises the 9-channel observation (repeating the
//! first frame after connect, matching the training-time FrameStack reset
//! semantics).

use std::collections::HashMap;

use anyhow::{ensure, Result};

/// One client's stacking state: up to 3 most-recent frames as normalised
/// 3-channel planes.
#[derive(Debug, Default)]
struct ClientState {
    /// each entry: 3*x*x floats (CHW)
    frames: Vec<Vec<f32>>,
    x: usize,
}

#[derive(Debug, Default)]
pub struct SessionManager {
    clients: HashMap<u32, ClientState>,
}

impl SessionManager {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn n_clients(&self) -> usize {
        self.clients.len()
    }

    pub fn disconnect(&mut self, client: u32) {
        self.clients.remove(&client);
    }

    /// Ingest an RGBA frame (4·x² bytes) and return the stacked 9×x×x
    /// observation (oldest→newest).
    pub fn ingest_rgba(&mut self, client: u32, x: usize, rgba: &[u8]) -> Result<Vec<f32>> {
        ensure!(rgba.len() == 4 * x * x, "rgba size {} != {}", rgba.len(), 4 * x * x);
        let st = self.clients.entry(client).or_default();
        if st.x != x {
            // resolution change (or first frame): reset the stack
            st.frames.clear();
            st.x = x;
        }
        // RGBA HWC u8 -> RGB CHW f32/255 (alpha dropped)
        let mut plane = vec![0.0f32; 3 * x * x];
        for y in 0..x {
            for xx in 0..x {
                let i = (y * x + xx) * 4;
                for c in 0..3 {
                    plane[c * x * x + y * x + xx] = rgba[i + c] as f32 / 255.0;
                }
            }
        }
        if st.frames.is_empty() {
            st.frames = vec![plane.clone(), plane.clone(), plane];
        } else {
            st.frames.push(plane);
            if st.frames.len() > 3 {
                st.frames.remove(0);
            }
        }
        let mut obs = Vec::with_capacity(9 * x * x);
        for f in &st.frames {
            obs.extend_from_slice(f);
        }
        Ok(obs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(x: usize, v: u8) -> Vec<u8> {
        let mut f = vec![v; 4 * x * x];
        // opaque alpha
        for a in f.iter_mut().skip(3).step_by(4) {
            *a = 255;
        }
        f
    }

    #[test]
    fn first_frame_repeats_three_times() {
        let mut s = SessionManager::new();
        let obs = s.ingest_rgba(1, 4, &frame(4, 100)).unwrap();
        assert_eq!(obs.len(), 9 * 16);
        let n = 3 * 16;
        assert_eq!(&obs[0..n], &obs[n..2 * n]);
        assert_eq!(&obs[n..2 * n], &obs[2 * n..3 * n]);
        assert!((obs[0] - 100.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn stack_slides() {
        let mut s = SessionManager::new();
        s.ingest_rgba(1, 4, &frame(4, 10)).unwrap();
        s.ingest_rgba(1, 4, &frame(4, 20)).unwrap();
        let obs = s.ingest_rgba(1, 4, &frame(4, 30)).unwrap();
        let n = 3 * 16;
        assert!((obs[0] - 10.0 / 255.0).abs() < 1e-6); // oldest first
        assert!((obs[n] - 20.0 / 255.0).abs() < 1e-6);
        assert!((obs[2 * n] - 30.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn clients_are_isolated() {
        let mut s = SessionManager::new();
        s.ingest_rgba(1, 4, &frame(4, 10)).unwrap();
        let obs2 = s.ingest_rgba(2, 4, &frame(4, 99)).unwrap();
        assert!((obs2[0] - 99.0 / 255.0).abs() < 1e-6);
        assert_eq!(s.n_clients(), 2);
        s.disconnect(1);
        assert_eq!(s.n_clients(), 1);
    }

    #[test]
    fn alpha_is_dropped() {
        let mut s = SessionManager::new();
        let mut f = frame(2, 0);
        f[3] = 77; // alpha byte should not appear anywhere
        let obs = s.ingest_rgba(1, 2, &f).unwrap();
        assert!(obs.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn wrong_size_rejected() {
        let mut s = SessionManager::new();
        assert!(s.ingest_rgba(1, 4, &[0; 10]).is_err());
    }

    #[test]
    fn resolution_change_resets_stack() {
        let mut s = SessionManager::new();
        s.ingest_rgba(1, 4, &frame(4, 10)).unwrap();
        let obs = s.ingest_rgba(1, 2, &frame(2, 50)).unwrap();
        assert_eq!(obs.len(), 9 * 4);
        let n = 3 * 4;
        assert_eq!(&obs[0..n], &obs[n..2 * n]); // fresh stack
    }
}

//! The split-policy serving coordinator — the paper's systems contribution
//! realised as an L3 Rust service: request [`router`], dynamic [`batcher`],
//! per-client [`session`] state, serving [`metrics`], the TCP [`server`],
//! and a simulated-device [`client`] fleet for load experiments.

pub mod arena;
pub mod batcher;
pub mod client;
pub mod metrics;
pub mod router;
pub mod server;
pub mod session;

pub use arena::BatchArena;
pub use batcher::{BatchCollector, BatchPolicy};
pub use client::{
    merged_latencies, run_client, run_fleet, run_learn_client, ClientConfig, ClientReport,
    LearnClientConfig, LearnClientReport,
};
pub use metrics::Metrics;
pub use router::{chunk_batches, pick_batch, Route};
pub use server::{serve, Backend, ServerConfig, ServerHandle, SimSpec};
pub use session::SessionManager;

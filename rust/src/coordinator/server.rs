//! The serving coordinator: a threaded TCP server that routes split-policy
//! and server-only requests through a dynamic batcher into the PJRT
//! executables.
//!
//! Thread layout (the xla Runtime is thread-confined, DESIGN.md §1):
//!   * accept thread — owns the listener, spawns one reader per connection;
//!   * reader threads — decode frames, enqueue work (with a shared writer
//!     handle for the reply);
//!   * executor thread — owns the Runtime, the BatchCollector, the
//!     SessionManager, and device-resident parameters; forms batches, runs
//!     the right executable from the batch ladder, writes responses.

use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use log::{debug, warn};

use crate::net::framing::{Hello, Msg, Payload, Response};
use crate::net::tcp::{read_msg, write_msg};
use crate::runtime::{DeviceTensor, Exe, Runtime, Value};

use super::batcher::{BatchCollector, BatchPolicy};
use super::metrics::Metrics;
use super::router::{pick_batch, Route};
use super::session::SessionManager;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// bind address; use port 0 for an ephemeral port
    pub addr: String,
    /// split-route encoder architecture (miniconv4 | miniconv16)
    pub arch: String,
    pub policy: BatchPolicy,
    /// per-route queue bound (back-pressure)
    pub max_depth: usize,
    pub artifact_dir: PathBuf,
    /// identity stamped into hello acks when this server runs as a fleet
    /// shard (None for a standalone coordinator)
    pub shard_id: Option<u16>,
    /// inference engine behind the batcher
    pub backend: Backend,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            arch: "miniconv4".into(),
            policy: BatchPolicy::default(),
            max_depth: 512,
            artifact_dir: crate::runtime::default_artifact_dir(),
            shard_id: None,
            backend: Backend::Pjrt,
        }
    }
}

/// Which engine executes batches.
#[derive(Debug, Clone)]
pub enum Backend {
    /// real AOT artifacts through the PJRT runtime (requires `make artifacts`)
    Pjrt,
    /// simulated accelerator: real batching/session/metrics machinery, but
    /// each batch costs `fixed + per_item * n` of executor wall time —
    /// serving-path experiments without artifacts. With `encode: true`,
    /// raw frames additionally run through the real compiled MiniConv-4
    /// shader pipeline (synthetic weights) and actions are derived from
    /// the features, so Sim shards exercise the genuine encoder hot path.
    Sim(SimSpec),
}

/// Cost model for the [`Backend::Sim`] accelerator.
#[derive(Debug, Clone, Copy)]
pub struct SimSpec {
    /// per-batch launch overhead
    pub fixed: Duration,
    /// marginal cost per batched item
    pub per_item: Duration,
    /// action vector width returned to clients
    pub action_dim: usize,
    /// run the compiled MiniConv-4 encoder over each RawRgba observation
    /// (real compute, folded into the modelled batch cost) instead of
    /// returning all-zero actions
    pub encode: bool,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            fixed: Duration::from_micros(500),
            per_item: Duration::from_micros(150),
            action_dim: 1,
            encode: false,
        }
    }
}

/// A unit of work as it moves from reader to executor.
struct Work {
    client: u32,
    id: u64,
    payload: Payload,
    received: Instant,
    reply: Arc<Mutex<TcpStream>>,
}

pub struct ServerHandle {
    pub addr: SocketAddr,
    pub metrics: Metrics,
    shutdown: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start the coordinator; returns once the socket is bound and the executor
/// has compiled its batch-1 executables (so first-request latency is sane).
pub fn serve(cfg: ServerConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    let metrics = Metrics::new();
    let shutdown = Arc::new(AtomicBool::new(false));
    let (tx, rx) = std::sync::mpsc::channel::<Work>();

    // executor thread (owns the PJRT runtime)
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
    let exec_metrics = metrics.clone();
    let exec_shutdown = shutdown.clone();
    let exec_cfg = cfg.clone();
    let executor = std::thread::Builder::new()
        .name("mc-executor".into())
        .spawn(move || executor_main(exec_cfg, rx, exec_metrics, exec_shutdown, ready_tx))
        .context("spawn executor")?;
    ready_rx
        .recv()
        .context("executor died during startup")??;

    // accept thread
    let acc_shutdown = shutdown.clone();
    let shard_id = cfg.shard_id;
    let acceptor = std::thread::Builder::new()
        .name("mc-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if acc_shutdown.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let tx = tx.clone();
                        let shutdown = acc_shutdown.clone();
                        std::thread::Builder::new()
                            .name("mc-reader".into())
                            .spawn(move || reader_main(s, tx, shutdown, shard_id))
                            .ok();
                    }
                    Err(e) => {
                        warn!("accept error: {e}");
                        break;
                    }
                }
            }
        })
        .context("spawn acceptor")?;

    Ok(ServerHandle { addr, metrics, shutdown, threads: vec![executor, acceptor] })
}

fn reader_main(
    stream: TcpStream,
    tx: Sender<Work>,
    shutdown: Arc<AtomicBool>,
    shard_id: Option<u16>,
) {
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(e) => {
            warn!("clone stream: {e}");
            return;
        }
    };
    let mut reader = stream;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match read_msg(&mut reader) {
            Ok(Some(Msg::Request(r))) => {
                let work = Work {
                    client: r.client,
                    id: r.id,
                    payload: r.payload,
                    received: Instant::now(),
                    reply: writer.clone(),
                };
                if tx.send(work).is_err() {
                    break; // executor gone
                }
            }
            Ok(Some(Msg::Hello(h))) => {
                // ack the preamble so gateways and health probes get a round
                // trip; the ack carries our shard identity
                let ack = Msg::Hello(Hello { client: h.client, split: h.split, shard: shard_id });
                let mut w = writer.lock().unwrap();
                if write_msg(&mut *w, &ack).is_err() {
                    break;
                }
            }
            Ok(Some(Msg::Response(_))) => {
                warn!("client sent a response; ignoring");
            }
            Ok(None) => break, // clean EOF
            Err(e) => {
                debug!("reader: {e}");
                break;
            }
        }
    }
}

/// Everything the executor needs for one route.
struct RouteExec {
    /// batch size -> compiled executable
    exes: HashMap<usize, Rc<Exe>>,
    ladder: Vec<usize>,
    params: DeviceTensor,
    prefix: String,
}

fn executor_main(
    cfg: ServerConfig,
    rx: Receiver<Work>,
    metrics: Metrics,
    shutdown: Arc<AtomicBool>,
    ready: Sender<Result<()>>,
) {
    match cfg.backend.clone() {
        Backend::Pjrt => executor_pjrt(cfg, rx, metrics, shutdown, ready),
        Backend::Sim(spec) => executor_sim(spec, cfg, rx, metrics, shutdown, ready),
    }
}

/// The batching loop shared by every backend: pull work, honour the batch
/// deadline, report drops, hand ready batches to `run`.
fn executor_loop<F>(
    policy: BatchPolicy,
    max_depth: usize,
    rx: Receiver<Work>,
    metrics: &Metrics,
    shutdown: &AtomicBool,
    mut run: F,
) where
    F: FnMut(Route, Vec<super::batcher::Item<Work>>) -> Result<()>,
{
    let mut collector: BatchCollector<Work> = BatchCollector::new(policy, max_depth);
    let mut dropped_reported = 0u64;

    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        // pull work: block briefly when idle, otherwise honour the batch
        // deadline
        let timeout = collector
            .next_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(w) => {
                let now = Instant::now();
                let admit = |w: Work, collector: &mut BatchCollector<Work>| {
                    let route = Route::of(&w.payload);
                    let (client, id, reply) = (w.client, w.id, w.reply.clone());
                    if !collector.push(route, w, now) {
                        // back-pressure: reject explicitly (empty action)
                        // so the client never blocks on a dropped request
                        let mut wtr = reply.lock().unwrap();
                        let _ = write_msg(
                            &mut *wtr,
                            &Msg::Response(Response { client, id, action: vec![] }),
                        );
                    }
                };
                admit(w, &mut collector);
                // opportunistically drain whatever else is queued
                while let Ok(w) = rx.try_recv() {
                    admit(w, &mut collector);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        if collector.dropped > dropped_reported {
            metrics.add_dropped(collector.dropped - dropped_reported);
            dropped_reported = collector.dropped;
        }

        while let Some(route) = collector.ready(Instant::now()) {
            let items = collector.take(route);
            if let Err(e) = run(route, items) {
                warn!("batch failed: {e:#}");
            }
        }
    }
}

fn executor_pjrt(
    cfg: ServerConfig,
    rx: Receiver<Work>,
    metrics: Metrics,
    shutdown: Arc<AtomicBool>,
    ready: Sender<Result<()>>,
) {
    let setup = (|| -> Result<(Runtime, RouteExec, RouteExec)> {
        let rt = Runtime::new(&cfg.artifact_dir)?;
        let serve_x = rt.manifest.serve_x;
        let head_prefix = format!("head_{}_x{serve_x}", cfg.arch);
        let full_prefix = format!("full_fullcnn_x{serve_x}");
        let head_params = Value::f32(
            &[rt.manifest.load_params(&format!("serve_head_{}", cfg.arch))?.len()],
            rt.manifest.load_params(&format!("serve_head_{}", cfg.arch))?,
        );
        let full_params = Value::f32(
            &[rt.manifest.load_params("serve_full_fullcnn")?.len()],
            rt.manifest.load_params("serve_full_fullcnn")?,
        );
        let mut split = RouteExec {
            exes: HashMap::new(),
            ladder: rt.manifest.batch_ladder(&head_prefix),
            params: rt.to_device(&head_params)?,
            prefix: head_prefix,
        };
        let mut full = RouteExec {
            exes: HashMap::new(),
            ladder: rt.manifest.batch_ladder(&full_prefix),
            params: rt.to_device(&full_params)?,
            prefix: full_prefix,
        };
        anyhow::ensure!(!split.ladder.is_empty(), "no head artifacts for {}", cfg.arch);
        anyhow::ensure!(!full.ladder.is_empty(), "no full artifacts");
        // precompile batch-1 so the first request isn't a compile stall
        let b1s = rt.load(&format!("{}_b1", split.prefix))?;
        let b1f = rt.load(&format!("{}_b1", full.prefix))?;
        split.exes.insert(1, b1s);
        full.exes.insert(1, b1f);
        Ok((rt, split, full))
    })();

    let (rt, mut split, mut full) = match setup {
        Ok(t) => {
            let _ = ready.send(Ok(()));
            t
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let mut sessions = SessionManager::new();
    executor_loop(cfg.policy, cfg.max_depth, rx, &metrics, &shutdown, |route, items| {
        let exec = match route {
            Route::Split => &mut split,
            Route::Full => &mut full,
        };
        run_batch(&rt, exec, route, items, &mut sessions, &metrics)
    });
}

/// The Sim backend's real-compute engine: compiled MiniConv-4 pipelines
/// (synthetic deterministic weights) keyed by observation side length,
/// plus a reused feature buffer — steady-state encodes don't allocate.
struct SimEncoder {
    pipes: HashMap<usize, crate::shader::CompiledPipeline>,
    feat: crate::tensor::Chw,
}

impl SimEncoder {
    fn new() -> Self {
        SimEncoder { pipes: HashMap::new(), feat: crate::tensor::Chw::zeros(1, 1, 1) }
    }

    /// Encode a stacked 9×x×x observation; returns `action_dim` per-channel
    /// feature means (deterministic, real compute).
    fn encode(&mut self, x: usize, obs: Vec<f32>, action_dim: usize) -> Result<Vec<f32>> {
        use std::collections::hash_map::Entry;
        let pipe = match self.pipes.entry(x) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(e) => {
                let ir = crate::experiments::execution::miniconv4_ir();
                let plan = crate::shader::plan(&ir, x)?;
                let mut rng = crate::util::rng::Rng::new(0xC0DE);
                let flat: Vec<f32> =
                    (0..ir.param_count()).map(|_| rng.normal_f32() * 0.3).collect();
                let weights = crate::shader::unpack_conv_weights(&ir, &flat)?;
                e.insert(crate::shader::CompiledPipeline::new(
                    plan,
                    weights,
                    crate::shader::TextureFormat::Float,
                )?)
            }
        };
        let obs = crate::tensor::Chw::from_vec(9, x, x, obs);
        pipe.run_into(&obs, &mut self.feat)?;
        let feat = &self.feat;
        let px = feat.h * feat.w;
        Ok((0..action_dim)
            .map(|c| {
                let ch = c % feat.c;
                let sum: f32 = feat.data[ch * px..(ch + 1) * px].iter().sum();
                sum / px as f32
            })
            .collect())
    }
}

fn executor_sim(
    spec: SimSpec,
    cfg: ServerConfig,
    rx: Receiver<Work>,
    metrics: Metrics,
    shutdown: Arc<AtomicBool>,
    ready: Sender<Result<()>>,
) {
    // no artifacts to stage: ready immediately
    let _ = ready.send(Ok(()));
    let mut sessions = SessionManager::new();
    let mut encoder = SimEncoder::new();
    executor_loop(cfg.policy, cfg.max_depth, rx, &metrics, &shutdown, |route, items| {
        run_batch_sim(&spec, route, items, &mut sessions, &mut encoder, &metrics)
    });
}

/// Sim-backend batch execution: real session stacking and metrics, modelled
/// compute time, and (with `encode`) real compiled-shader encodes.
fn run_batch_sim(
    spec: &SimSpec,
    route: Route,
    items: Vec<super::batcher::Item<Work>>,
    sessions: &mut SessionManager,
    encoder: &mut SimEncoder,
    metrics: &Metrics,
) -> Result<()> {
    let n = items.len();
    let dequeue = Instant::now();
    let queue_waits: Vec<Duration> =
        items.iter().map(|i| dequeue.duration_since(i.work.received)).collect();

    // raw frames still flow through the per-client frame stack so shard-local
    // session state stays meaningful under the fleet gateway (outside the
    // modelled window, exactly as before this PR)
    let mut to_encode: Vec<(usize, usize, Vec<f32>)> = Vec::new();
    for (i, item) in items.iter().enumerate() {
        if let Payload::RawRgba { x, data } = &item.work.payload {
            let obs = sessions.ingest_rgba(item.work.client, *x as usize, data)?;
            // a zero-sized frame has nothing to encode (and a 0-pixel plan
            // would be degenerate): fall back to the zero-action reply
            if spec.encode && *x > 0 {
                to_encode.push((i, *x as usize, obs));
            }
        }
    }

    // the modelled accelerator: launch overhead + linear per-item cost.
    // Real compiled-shader encodes run inside the window and only their
    // own time is deducted, so encode:false batches sleep the full budget.
    let t_exec = Instant::now();
    let mut actions: HashMap<usize, Vec<f32>> = HashMap::new();
    for (i, x, obs) in to_encode {
        actions.insert(i, encoder.encode(x, obs, spec.action_dim)?);
    }
    let modelled = spec.fixed + spec.per_item * n as u32;
    let spent = t_exec.elapsed();
    if modelled > spent {
        std::thread::sleep(modelled - spent);
    }
    let exec_time = t_exec.elapsed();

    let services: Vec<Duration> = items.iter().map(|i| i.work.received.elapsed()).collect();
    metrics.record_batch(route, n, 0, &queue_waits, exec_time, &services);

    for (i, item) in items.iter().enumerate() {
        let action = actions.remove(&i).unwrap_or_else(|| vec![0.0; spec.action_dim]);
        let resp = Msg::Response(Response {
            client: item.work.client,
            id: item.work.id,
            action,
        });
        let mut w = item.work.reply.lock().unwrap();
        if let Err(e) = write_msg(&mut *w, &resp) {
            debug!("reply to client {}: {e}", item.work.client);
        }
        let _ = w.flush();
    }
    Ok(())
}

fn run_batch(
    rt: &Runtime,
    exec: &mut RouteExec,
    route: Route,
    items: Vec<super::batcher::Item<Work>>,
    sessions: &mut SessionManager,
    metrics: &Metrics,
) -> Result<()> {
    let n = items.len();
    let b = pick_batch(n, &exec.ladder);
    let dequeue = Instant::now();
    let queue_waits: Vec<Duration> =
        items.iter().map(|i| dequeue.duration_since(i.work.received)).collect();

    // compile-on-first-use per ladder entry
    if !exec.exes.contains_key(&b) {
        let exe = rt.load(&format!("{}_b{b}", exec.prefix))?;
        exec.exes.insert(b, exe);
    }
    let exe = exec.exes[&b].clone();

    // assemble the batched input tensor
    let in_spec = &exe.spec.inputs[1];
    let per_item: usize = in_spec.shape[1..].iter().product();
    let mut data = vec![0.0f32; in_spec.elems()];
    for (i, item) in items.iter().enumerate() {
        let dst = &mut data[i * per_item..(i + 1) * per_item];
        match &item.work.payload {
            Payload::RawRgba { x, data: rgba } => {
                let obs = sessions.ingest_rgba(item.work.client, *x as usize, rgba)?;
                anyhow::ensure!(obs.len() == per_item, "obs len {} != {per_item}", obs.len());
                dst.copy_from_slice(&obs);
            }
            Payload::Features { scale, data: q, .. } => {
                anyhow::ensure!(q.len() == per_item, "feat len {} != {per_item}", q.len());
                // hoist the per-byte div out of the dequant loop
                let step = scale / 255.0;
                for (o, &byte) in dst.iter_mut().zip(q.iter()) {
                    *o = byte as f32 * step;
                }
            }
        }
    }

    // execute with device-resident params (host batch staged per call)
    let t_exec = Instant::now();
    let batch_val = Value::f32(&in_spec.shape, data);
    let batch_dev = rt.to_device(&batch_val)?;
    let out = exe.run_device(&[&exec.params, &batch_dev])?;
    let exec_time = t_exec.elapsed();

    let actions = out[0].as_f32()?;
    let adim = exe.spec.outputs[0].shape[1];

    // record metrics BEFORE writing responses: a client that just received
    // its action must observe its request in the metrics snapshot
    let services: Vec<Duration> = items.iter().map(|i| i.work.received.elapsed()).collect();
    metrics.record_batch(route, n, b - n, &queue_waits, exec_time, &services);

    // respond
    for (i, item) in items.iter().enumerate() {
        let resp = Msg::Response(Response {
            client: item.work.client,
            id: item.work.id,
            action: actions[i * adim..(i + 1) * adim].to_vec(),
        });
        let mut w = item.work.reply.lock().unwrap();
        if let Err(e) = write_msg(&mut *w, &resp) {
            debug!("reply to client {}: {e}", item.work.client);
        }
        let _ = w.flush();
    }
    Ok(())
}

//! The serving coordinator: a threaded TCP server that routes split-policy
//! and server-only requests through a dynamic batcher into the PJRT
//! executables.
//!
//! Thread layout (the xla Runtime is thread-confined, DESIGN.md §1):
//!   * accept thread — owns the listener, spawns one reader per connection;
//!   * reader threads — decode frames, enqueue work (with a shared writer
//!     handle for the reply);
//!   * executor thread — owns the Runtime, the BatchCollector, the
//!     SessionManager, and device-resident parameters; forms batches, runs
//!     the right executable from the batch ladder, writes responses.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use log::{debug, warn};

use crate::codec::Decoders;
use crate::learn::{Learner, LearnerConfig, PolicyStore};
use crate::net::framing::{
    dequantize_features_into, encode_response_into, encode_response_learn_into,
    encode_response_v2_into, ErrorMsg, Msg, Payload, Response, ResponseV2, CAP_EXPERIENCE,
    CAP_TRACE, ERR_EXPERIENCE_UNSUPPORTED, RESP_FLAG_NEED_KEYFRAME,
};
use crate::net::limits::{LimitsConfig, SessionGate};
use crate::net::tcp::{read_msg_traced, write_frame, write_msg};
use crate::runtime::{DeviceTensor, Exe, Runtime, Value};
use crate::sim::clock::ClockHandle;
use crate::trace::{self, TraceCtx};

use super::arena::BatchArena;
use super::batcher::{BatchCollector, BatchPolicy};
use super::metrics::Metrics;
use super::router::{pick_batch, Route};
use super::session::SessionManager;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// bind address; use port 0 for an ephemeral port
    pub addr: String,
    /// split-route encoder architecture (miniconv4 | miniconv16)
    pub arch: String,
    pub policy: BatchPolicy,
    /// per-route queue bound (back-pressure)
    pub max_depth: usize,
    pub artifact_dir: PathBuf,
    /// identity stamped into hello acks when this server runs as a fleet
    /// shard (None for a standalone coordinator)
    pub shard_id: Option<u16>,
    /// inference engine behind the batcher
    pub backend: Backend,
    /// online learning (DESIGN.md §8): when set, sessions may negotiate
    /// [`CAP_EXPERIENCE`] and stream experience frames; the executor
    /// runs a shard-local [`Learner`] over them. `None` disables the
    /// capability — experience frames are answered with an explicit
    /// error frame so clients fall back to inference-only.
    pub learn: Option<LearnerConfig>,
    /// time source for queue-wait stamps, batch deadlines, and the Sim
    /// backend's modelled waits (the clock seam, DESIGN.md §6). Keep this
    /// the wall clock for a live server: the executor blocks in real-time
    /// `recv_timeout` between batches, so a virtual clock would stall the
    /// `max_wait` deadline. Fully virtual-time serving goes through the
    /// single-threaded `sim::scenario` runner instead, which drives the
    /// same batcher/session components event by event.
    pub clock: ClockHandle,
    /// hostile-input resource budgets (DESIGN.md §9): per-type frame-size
    /// caps negotiated at Hello, per-connection malformed-frame budgets
    /// with quarantine, and the reader idle timeout that reaps half-open
    /// clients together with their session + codec state
    pub limits: LimitsConfig,
    /// per-decision distributed tracing (DESIGN.md §12): when set, sessions
    /// may negotiate [`CAP_TRACE`] and carry a trace trailer on every
    /// decision frame; the server stamps its enqueue/dequeue/pack/execute/
    /// reply hops, echoes the trailer on replies, and retains the recent
    /// spans in the metrics flight recorder. Off by default: untraced
    /// deployments pay nothing, not even the capability grant.
    pub trace: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            arch: "miniconv4".into(),
            policy: BatchPolicy::default(),
            max_depth: 512,
            artifact_dir: crate::runtime::default_artifact_dir(),
            shard_id: None,
            backend: Backend::Pjrt,
            learn: None,
            clock: ClockHandle::wall(),
            limits: LimitsConfig::default(),
            trace: false,
        }
    }
}

/// Which engine executes batches.
#[derive(Debug, Clone)]
pub enum Backend {
    /// real AOT artifacts through the PJRT runtime (requires `make artifacts`)
    Pjrt,
    /// simulated accelerator: real batching/session/metrics machinery, but
    /// each batch costs `fixed + per_item * n` of executor wall time —
    /// serving-path experiments without artifacts. With `encode: true`,
    /// raw frames additionally run through the real compiled MiniConv-4
    /// shader pipeline (synthetic weights) and actions are derived from
    /// the features, so Sim shards exercise the genuine encoder hot path.
    Sim(SimSpec),
}

/// Cost model for the [`Backend::Sim`] accelerator.
#[derive(Debug, Clone, Copy)]
pub struct SimSpec {
    /// per-batch launch overhead
    pub fixed: Duration,
    /// marginal cost per batched item
    pub per_item: Duration,
    /// action vector width returned to clients
    pub action_dim: usize,
    /// run the compiled MiniConv-4 encoder over each RawRgba observation
    /// (real compute, folded into the modelled batch cost) instead of
    /// returning all-zero actions
    pub encode: bool,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            fixed: Duration::from_micros(500),
            per_item: Duration::from_micros(150),
            action_dim: 1,
            encode: false,
        }
    }
}

/// A unit of work as it moves from reader to executor.
struct Work {
    client: u32,
    id: u64,
    payload: Payload,
    received: Instant,
    /// the decision's trace span when its session negotiated [`CAP_TRACE`]:
    /// peeled off the request frame by the reader (enqueue hop already
    /// stamped), completed by the executor, echoed on the reply
    trace: Option<TraceCtx>,
    /// the connection's shared writer: wrapped in an `Arc` once per
    /// connection by the reader and shared across every work item queued
    /// from it — enqueueing and replying never clone the stream, and the
    /// executor only touches the handle it was given
    reply: Arc<Mutex<TcpStream>>,
}

/// What reader threads feed the executor: requests, plus session
/// lifecycle edges so the executor can invalidate per-client codec state
/// on every (re)connect — a new session incarnation must keyframe before
/// it can delta (DESIGN.md §7) — and free it when the connection ends
/// (the decoder map must not grow with churning client ids).
enum Ingress {
    Work(Work),
    Hello { client: u32 },
    Disconnect { client: u32 },
}

/// One executor-thread event, dispatched through a single closure so
/// batch execution and codec-state invalidation share the same mutable
/// backend state (sessions, decoders, arena).
enum ExecEvent<'a> {
    /// a formed batch, borrowed from the executor's pooled batch buffer
    Batch(Route, &'a [super::batcher::Item<Work>]),
    /// an experience frame (handled in ingress order, never batched: the
    /// per-client (ep, step) discipline wants strict ordering, and the
    /// gradient work is already amortised by segment batching in the
    /// [`crate::learn::ExperienceBuffer`])
    Experience(Work),
    /// a session's connect preamble reached this server
    Hello(u32),
    /// a session's connection closed
    Disconnect(u32),
}

/// Back-pressure rejection reply: explicit empty action so the client
/// never blocks on a dropped request. Sessions on the codec format also
/// learn their frame never reached the decoder (`need_keyframe`), so the
/// delta chain re-keys instead of desyncing.
fn reject_work(w: Work, clock: &ClockHandle) {
    let msg = match &w.payload {
        Payload::FeaturesV2(f) => Msg::ResponseV2(ResponseV2 {
            client: w.client,
            id: w.id,
            seq: f.seq,
            flags: RESP_FLAG_NEED_KEYFRAME,
            queue_wait_us: 0,
            action: vec![],
        }),
        _ => Msg::Response(Response { client: w.client, id: w.id, action: vec![] }),
    };
    let mut wtr = w.reply.lock().unwrap();
    // a traced session must get its trailer back even on the rejection
    // path — a contract the client's strict split relies on
    if let Some(mut t) = w.trace {
        t.stamp(trace::STAGE_REPLY, trace::now_ns(clock));
        let mut frame = msg.encode();
        trace::append_trace(&mut frame, &t);
        let _ = write_frame(&mut *wtr, &frame);
    } else {
        let _ = write_msg(&mut *wtr, &msg);
    }
}

pub struct ServerHandle {
    pub addr: SocketAddr,
    pub metrics: Metrics,
    shutdown: Arc<AtomicBool>,
    /// fleet topology epoch this shard currently serves under; readers
    /// adopt it per-hello, so a gateway pushing an update here makes
    /// every subsequent stale/forged epoch hello refuse (DESIGN.md §10)
    topology_epoch: Arc<AtomicU64>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Propagate a fleet topology epoch to this shard's admission gates.
    pub fn set_topology_epoch(&self, epoch: u64) {
        self.topology_epoch.store(epoch, Ordering::SeqCst);
    }

    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start the coordinator; returns once the socket is bound and the executor
/// has compiled its batch-1 executables (so first-request latency is sane).
pub fn serve(cfg: ServerConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    let metrics = Metrics::new();
    let shutdown = Arc::new(AtomicBool::new(false));
    let (tx, rx) = std::sync::mpsc::channel::<Ingress>();

    // executor thread (owns the PJRT runtime)
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
    let exec_metrics = metrics.clone();
    let exec_shutdown = shutdown.clone();
    let exec_cfg = cfg.clone();
    let executor = std::thread::Builder::new()
        .name("mc-executor".into())
        .spawn(move || executor_main(exec_cfg, rx, exec_metrics, exec_shutdown, ready_tx))
        .context("spawn executor")?;
    ready_rx
        .recv()
        .context("executor died during startup")??;

    // accept thread
    let acc_shutdown = shutdown.clone();
    let shard_id = cfg.shard_id;
    let caps_mask = (if cfg.learn.is_some() { CAP_EXPERIENCE } else { 0 })
        | (if cfg.trace { CAP_TRACE } else { 0 });
    let acc_clock = cfg.clock.clone();
    let acc_limits = cfg.limits.clone();
    let topology_epoch = Arc::new(AtomicU64::new(0));
    let acc_epoch = topology_epoch.clone();
    let acceptor = std::thread::Builder::new()
        .name("mc-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if acc_shutdown.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let tx = tx.clone();
                        let shutdown = acc_shutdown.clone();
                        let clock = acc_clock.clone();
                        let limits = acc_limits.clone();
                        let epoch = acc_epoch.clone();
                        std::thread::Builder::new()
                            .name("mc-reader".into())
                            .spawn(move || {
                                reader_main(
                                    s, tx, shutdown, shard_id, caps_mask, clock, limits, epoch,
                                )
                            })
                            .ok();
                    }
                    Err(e) => {
                        warn!("accept error: {e}");
                        break;
                    }
                }
            }
        })
        .context("spawn acceptor")?;

    Ok(ServerHandle { addr, metrics, shutdown, topology_epoch, threads: vec![executor, acceptor] })
}

#[allow(clippy::too_many_arguments)]
fn reader_main(
    stream: TcpStream,
    tx: Sender<Ingress>,
    shutdown: Arc<AtomicBool>,
    shard_id: Option<u16>,
    caps_mask: u8,
    clock: ClockHandle,
    limits: LimitsConfig,
    topology_epoch: Arc<AtomicU64>,
) {
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(e) => {
            warn!("clone stream: {e}");
            return;
        }
    };
    // a half-open client (sends nothing, never closes) must not pin this
    // OS thread forever: the read timeout doubles as the idle reaper —
    // on expiry the connection is dropped and its session + codec state
    // freed through the normal Disconnect path
    if let Err(e) = stream.set_read_timeout(Some(limits.idle_timeout)) {
        warn!("set read timeout: {e}");
    }
    let mut reader = stream;
    // the session this connection carries (learned from its first frame),
    // so its codec stream state can be freed when the connection ends
    let mut session: Option<u32> = None;
    // admission state machine (DESIGN.md §9): pre-Hello frame caps, the
    // negotiated route/codec/caps after the Hello, and the per-connection
    // malformed-frame budget
    let mut gate = SessionGate::new(limits);
    let mut buf = Vec::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match read_msg_traced(&mut reader, &mut buf, gate.limits(), gate.grants(CAP_TRACE)) {
            Ok(Some(Ok((Msg::Request(r), ctx)))) => {
                session = Some(r.client);
                if matches!(r.payload, Payload::Experience(_)) && !gate.grants(CAP_EXPERIENCE) {
                    // explicit rejection (never silence): the client sees
                    // exactly why and falls back to inference-only frames
                    let err = Msg::Error(ErrorMsg {
                        client: r.client,
                        code: ERR_EXPERIENCE_UNSUPPORTED,
                        detail: "experience frames were not negotiated on this session".into(),
                    });
                    let mut w = writer.lock().unwrap();
                    if write_msg(&mut *w, &err).is_err() {
                        break;
                    }
                    continue;
                }
                // the transport already enforced the per-type size cap;
                // this meters the pre-Hello byte budget (a peer streaming
                // requests without ever negotiating is bounded)
                if let Err(e) = gate.admit(buf[0], buf.len()) {
                    warn!("client {}: {e:#}; disconnecting", r.client);
                    break;
                }
                let received = clock.now();
                let work = Work {
                    client: r.client,
                    id: r.id,
                    payload: r.payload,
                    received,
                    trace: ctx.map(|mut t| {
                        t.stamp(trace::STAGE_ENQUEUE, trace::ns_since_epoch(received));
                        t
                    }),
                    reply: writer.clone(),
                };
                if tx.send(Ingress::Work(work)).is_err() {
                    break; // executor gone
                }
            }
            Ok(Some(Ok((Msg::Hello(h), _)))) => {
                session = Some(h.client);
                // tell the executor first (channel order guarantees the
                // invalidation lands before any request this connection
                // sends), then ack the preamble so gateways and health
                // probes get a round trip; the ack carries our shard
                // identity, echoes the codec we accept, and masks the
                // requested capability bits — and fixes the per-type
                // frame caps to the negotiated route
                if tx.send(Ingress::Hello { client: h.client }).is_err() {
                    break;
                }
                // adopt the fleet's current epoch so a hello carrying a
                // stale or forged topology epoch refuses (DESIGN.md §10)
                gate.set_topology_epoch(topology_epoch.load(Ordering::SeqCst));
                let Some(ack) = gate.on_hello(&h, caps_mask, shard_id) else {
                    break; // quarantined or epoch-refused: no ack
                };
                let mut w = writer.lock().unwrap();
                if write_msg(&mut *w, &Msg::Hello(ack)).is_err() {
                    break;
                }
            }
            Ok(Some(Ok((
                Msg::Response(_) | Msg::ResponseV2(_) | Msg::ResponseLearn(_) | Msg::Error(_)
                | Msg::Policy(_),
                _,
            )))) => {
                warn!("client sent a server-side frame; ignoring");
            }
            Ok(Some(Err(e))) => {
                // well-framed but undecodable: framing is still
                // synchronized, so spend the malformed-frame budget
                // instead of tearing the session down on one bad frame
                if gate.on_decode_error() {
                    warn!(
                        "client {:?}: malformed-frame budget exhausted ({e:#}); quarantining",
                        session
                    );
                    break;
                }
                debug!("reader: malformed frame ({e:#}); budget remaining");
            }
            Ok(None) => break, // clean EOF
            Err(e) => {
                let timed_out = e
                    .root_cause()
                    .downcast_ref::<std::io::Error>()
                    .map(|io| {
                        matches!(
                            io.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        )
                    })
                    .unwrap_or(false);
                if timed_out {
                    debug!("reader: idle timeout; reaping session {session:?}");
                } else {
                    debug!("reader: {e}");
                }
                break;
            }
        }
    }
    // free the session's codec stream state. A reconnect's fresh Hello can
    // race this (separate reader threads, one channel): at worst the new
    // incarnation's state is evicted once, its next delta is refused with
    // need_keyframe, and the chain re-keys — bounded memory wins
    if let Some(client) = session {
        let _ = tx.send(Ingress::Disconnect { client });
    }
}

/// Everything the executor needs for one route.
struct RouteExec {
    /// batch size -> compiled executable
    exes: HashMap<usize, Rc<Exe>>,
    ladder: Vec<usize>,
    params: DeviceTensor,
    prefix: String,
    /// preallocated output `Value` storage, reused across batches
    outs: Vec<Value>,
}

/// Shard-local online learning behind the executor (DESIGN.md §8): the
/// [`Learner`] plus a local [`PolicyStore`] so direct-connected
/// (non-gateway) deployments still hand out monotonically versioned
/// snapshots. Published parameters are self-adopted immediately, so the
/// acting policy lags the latest version by at most one publish and the
/// staleness gate is trivially satisfied; gateway-coordinated fan-out
/// (where real lag appears) is modelled by the simnet scenario runner.
struct LearnExec {
    learner: Learner,
    store: PolicyStore,
    /// pooled dequantised-observation scratch
    obs: Vec<f32>,
    /// pooled reply frame
    frame: Vec<u8>,
}

impl LearnExec {
    fn new(cfg: LearnerConfig) -> LearnExec {
        LearnExec {
            learner: Learner::new(cfg),
            store: PolicyStore::new(),
            obs: Vec::new(),
            frame: Vec::new(),
        }
    }

    /// Decode, learn, act, reply. An undecodable codec frame answers with
    /// an empty need-keyframe reply, exactly like the inference path.
    fn handle(
        &mut self,
        codecs: &mut Decoders,
        w: &Work,
        max_rejects: u32,
        clock: &ClockHandle,
    ) -> Result<()> {
        let Payload::Experience(e) = &w.payload else { return Ok(()) };
        // experience frames are never batched: dequeue is now
        let dequeue_ns = trace::now_ns(clock);
        let flen = e.feat.feat_len();
        self.obs.clear();
        self.obs.resize(flen, 0.0);
        if codecs.decode_into(w.client, &e.feat, &mut self.obs).is_err() {
            quarantine_codec_abuser(codecs, w, max_rejects);
            encode_response_learn_into(
                w.client,
                w.id,
                e.feat.seq,
                RESP_FLAG_NEED_KEYFRAME,
                self.learner.acting_version,
                self.store.version(),
                &[],
                &mut self.frame,
            );
        } else {
            let step = self.learner.on_frame(
                w.client,
                &self.obs,
                e.ep,
                e.step,
                e.has_reward(),
                e.reward,
                e.done(),
                e.terminated(),
            )?;
            if let Some(params) = step.publish {
                let v = self.store.publish(&params);
                self.learner.adopt(v, &params)?;
            }
            encode_response_learn_into(
                w.client,
                w.id,
                e.feat.seq,
                0,
                step.acting_version,
                self.store.version(),
                &step.action,
                &mut self.frame,
            );
        }
        if let Some(mut t) = w.trace {
            t.stamp(trace::STAGE_DEQUEUE, dequeue_ns);
            t.stamp(trace::STAGE_REPLY, trace::now_ns(clock));
            trace::append_trace(&mut self.frame, &t);
        }
        let mut wtr = w.reply.lock().unwrap();
        if let Err(e) = write_frame(&mut *wtr, &self.frame) {
            debug!("learn reply to client {}: {e}", w.client);
        }
        Ok(())
    }
}

/// Codec-abuser quarantine (DESIGN.md §9): a session whose frames keep
/// failing the stream decoder past the consecutive-reject budget is cut
/// off at the socket. The counter resets on any accepted frame, so a
/// healthy delta client that takes a chain break recovers on its next
/// keyframe with at most one reject — only a peer that ignores the
/// need-keyframe feedback ever reaches the budget. Shutting the stream
/// down trips that connection's reader, which frees the session's codec
/// and stacking state through the normal Disconnect path; other
/// sessions' decoder state is never touched.
fn quarantine_codec_abuser(codecs: &Decoders, work: &Work, max_rejects: u32) {
    if codecs.consecutive_rejects(work.client) > max_rejects {
        warn!("client {}: codec-reject budget exhausted; quarantining", work.client);
        let w = work.reply.lock().unwrap();
        let _ = w.shutdown(std::net::Shutdown::Both);
    }
}

fn executor_main(
    cfg: ServerConfig,
    rx: Receiver<Ingress>,
    metrics: Metrics,
    shutdown: Arc<AtomicBool>,
    ready: Sender<Result<()>>,
) {
    match cfg.backend.clone() {
        Backend::Pjrt => executor_pjrt(cfg, rx, metrics, shutdown, ready),
        Backend::Sim(spec) => executor_sim(spec, cfg, rx, metrics, shutdown, ready),
    }
}

/// The batching loop shared by every backend: pull ingress, honour the
/// batch deadline, report drops, hand ready batches (and session
/// preambles) to `run`.
///
/// Batches are drained into one pooled `Vec<Item<Work>>` that lives for
/// the executor's lifetime — `run` borrows the batch, it never owns it,
/// so the steady-state loop performs no per-batch allocation.
fn executor_loop<F>(
    policy: BatchPolicy,
    max_depth: usize,
    rx: Receiver<Ingress>,
    metrics: &Metrics,
    shutdown: &AtomicBool,
    clock: &ClockHandle,
    mut run: F,
) where
    F: FnMut(ExecEvent) -> Result<()>,
{
    let mut collector: BatchCollector<Work> = BatchCollector::new(policy, max_depth);
    let mut batch: Vec<super::batcher::Item<Work>> = Vec::new();
    let mut dropped_reported = 0u64;

    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        // pull ingress: block briefly when idle, otherwise honour the
        // batch deadline
        let timeout = collector
            .next_deadline(clock.now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(first) => {
                let now = clock.now();
                // drain the first event and whatever else is queued
                let mut next = Some(first);
                while let Some(ing) = next {
                    match ing {
                        Ingress::Hello { client } => {
                            if let Err(e) = run(ExecEvent::Hello(client)) {
                                warn!("session preamble failed: {e:#}");
                            }
                        }
                        Ingress::Disconnect { client } => {
                            if let Err(e) = run(ExecEvent::Disconnect(client)) {
                                warn!("session teardown failed: {e:#}");
                            }
                        }
                        Ingress::Work(w) => {
                            if matches!(w.payload, Payload::Experience(_)) {
                                // never batched: strict ingress order
                                if let Err(e) = run(ExecEvent::Experience(w)) {
                                    warn!("experience frame failed: {e:#}");
                                }
                            } else {
                                // a saturated push hands the work back, so
                                // the reply handle is only touched (and
                                // never cloned) on the rejection path
                                let route = Route::of(&w.payload);
                                if let Some(rejected) = collector.push(route, w, now) {
                                    reject_work(rejected, clock);
                                }
                            }
                        }
                    }
                    next = rx.try_recv().ok();
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        if collector.dropped > dropped_reported {
            metrics.add_dropped(collector.dropped - dropped_reported);
            dropped_reported = collector.dropped;
        }

        while let Some(route) = collector.ready(clock.now()) {
            collector.take_into(route, &mut batch);
            if let Err(e) = run(ExecEvent::Batch(route, &batch)) {
                warn!("batch failed: {e:#}");
            }
            // drop the items now (payload buffers, reply-handle Arcs) so an
            // idle executor never pins client sockets; capacity stays pooled
            batch.clear();
        }
    }
}

fn executor_pjrt(
    cfg: ServerConfig,
    rx: Receiver<Ingress>,
    metrics: Metrics,
    shutdown: Arc<AtomicBool>,
    ready: Sender<Result<()>>,
) {
    let setup = (|| -> Result<(Runtime, RouteExec, RouteExec)> {
        let rt = Runtime::new(&cfg.artifact_dir)?;
        let serve_x = rt.manifest.serve_x;
        let head_prefix = format!("head_{}_x{serve_x}", cfg.arch);
        let full_prefix = format!("full_fullcnn_x{serve_x}");
        let head_params = Value::f32(
            &[rt.manifest.load_params(&format!("serve_head_{}", cfg.arch))?.len()],
            rt.manifest.load_params(&format!("serve_head_{}", cfg.arch))?,
        );
        let full_params = Value::f32(
            &[rt.manifest.load_params("serve_full_fullcnn")?.len()],
            rt.manifest.load_params("serve_full_fullcnn")?,
        );
        let mut split = RouteExec {
            exes: HashMap::new(),
            ladder: rt.manifest.batch_ladder(&head_prefix),
            params: rt.to_device(&head_params)?,
            prefix: head_prefix,
            outs: Vec::new(),
        };
        let mut full = RouteExec {
            exes: HashMap::new(),
            ladder: rt.manifest.batch_ladder(&full_prefix),
            params: rt.to_device(&full_params)?,
            prefix: full_prefix,
            outs: Vec::new(),
        };
        anyhow::ensure!(!split.ladder.is_empty(), "no head artifacts for {}", cfg.arch);
        anyhow::ensure!(!full.ladder.is_empty(), "no full artifacts");
        // precompile batch-1 so the first request isn't a compile stall
        let b1s = rt.load(&format!("{}_b1", split.prefix))?;
        let b1f = rt.load(&format!("{}_b1", full.prefix))?;
        split.exes.insert(1, b1s);
        full.exes.insert(1, b1f);
        Ok((rt, split, full))
    })();

    let (rt, mut split, mut full) = match setup {
        Ok(t) => {
            let _ = ready.send(Ok(()));
            t
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let mut sessions = SessionManager::new();
    let mut codecs = Decoders::new();
    let mut arena = BatchArena::new();
    let mut learn = cfg.learn.clone().map(LearnExec::new);
    let clock = cfg.clock.clone();
    let max_rejects = cfg.limits.max_codec_rejects;
    executor_loop(cfg.policy, cfg.max_depth, rx, &metrics, &shutdown, &clock, |ev| match ev {
        ExecEvent::Hello(client) => {
            // new session incarnation: its next codec frame must keyframe
            codecs.invalidate(client);
            Ok(())
        }
        ExecEvent::Disconnect(client) => {
            // reap everything the session pinned: codec stream state,
            // frame-stacking state, and buffered experience segments
            codecs.disconnect(client);
            sessions.disconnect(client);
            if let Some(l) = learn.as_mut() {
                l.learner.buf.drop_client(client);
            }
            Ok(())
        }
        ExecEvent::Experience(w) => match learn.as_mut() {
            Some(l) => l.handle(&mut codecs, &w, max_rejects, &clock),
            // unreachable behind the reader's caps gate; drop defensively
            None => Ok(()),
        },
        ExecEvent::Batch(route, items) => {
            let exec = match route {
                Route::Split => &mut split,
                Route::Full => &mut full,
            };
            run_batch(
                &rt,
                exec,
                route,
                items,
                &mut sessions,
                &mut codecs,
                &mut arena,
                &metrics,
                &cfg.clock,
                max_rejects,
            )
        }
    });
}

/// The Sim backend's real-compute engine: compiled MiniConv-4 pipelines
/// (synthetic deterministic weights) keyed by observation side length,
/// plus reused observation/feature buffers — steady-state encodes don't
/// allocate.
struct SimEncoder {
    pipes: HashMap<usize, crate::shader::CompiledPipeline>,
    obs: crate::tensor::Chw,
    feat: crate::tensor::Chw,
    /// (batch row, side length) of raw items to encode this batch (pooled)
    to_encode: Vec<(usize, usize)>,
}

impl SimEncoder {
    fn new() -> Self {
        SimEncoder {
            pipes: HashMap::new(),
            obs: crate::tensor::Chw::zeros(1, 1, 1),
            feat: crate::tensor::Chw::zeros(1, 1, 1),
            to_encode: Vec::new(),
        }
    }

    /// Encode a stacked 9×x×x observation (borrowed from its arena batch
    /// row), writing per-channel feature means into `out` (deterministic,
    /// real compute, no steady-state allocation).
    fn encode_into(&mut self, x: usize, obs: &[f32], out: &mut [f32]) -> Result<()> {
        use std::collections::hash_map::Entry;
        let pipe = match self.pipes.entry(x) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(e) => {
                let ir = crate::experiments::execution::miniconv4_ir();
                let plan = crate::shader::plan(&ir, x)?;
                let mut rng = crate::util::rng::Rng::new(0xC0DE);
                let flat: Vec<f32> =
                    (0..ir.param_count()).map(|_| rng.normal_f32() * 0.3).collect();
                let weights = crate::shader::unpack_conv_weights(&ir, &flat)?;
                e.insert(crate::shader::CompiledPipeline::new(
                    plan,
                    weights,
                    crate::shader::TextureFormat::Float,
                )?)
            }
        };
        self.obs.c = 9;
        self.obs.h = x;
        self.obs.w = x;
        self.obs.data.clear();
        self.obs.data.extend_from_slice(obs);
        pipe.run_into(&self.obs, &mut self.feat)?;
        let feat = &self.feat;
        let px = feat.h * feat.w;
        for (c, o) in out.iter_mut().enumerate() {
            let ch = c % feat.c;
            let sum: f32 = feat.data[ch * px..(ch + 1) * px].iter().sum();
            *o = sum / px as f32;
        }
        Ok(())
    }
}

fn executor_sim(
    spec: SimSpec,
    cfg: ServerConfig,
    rx: Receiver<Ingress>,
    metrics: Metrics,
    shutdown: Arc<AtomicBool>,
    ready: Sender<Result<()>>,
) {
    // no artifacts to stage: ready immediately
    let _ = ready.send(Ok(()));
    let mut sessions = SessionManager::new();
    let mut codecs = Decoders::new();
    let mut encoder = SimEncoder::new();
    let mut arena = BatchArena::new();
    let mut learn = cfg.learn.clone().map(LearnExec::new);
    let clock = cfg.clock.clone();
    let max_rejects = cfg.limits.max_codec_rejects;
    executor_loop(cfg.policy, cfg.max_depth, rx, &metrics, &shutdown, &clock, |ev| match ev {
        ExecEvent::Hello(client) => {
            codecs.invalidate(client);
            Ok(())
        }
        ExecEvent::Disconnect(client) => {
            codecs.disconnect(client);
            sessions.disconnect(client);
            if let Some(l) = learn.as_mut() {
                l.learner.buf.drop_client(client);
            }
            Ok(())
        }
        ExecEvent::Experience(w) => match learn.as_mut() {
            Some(l) => l.handle(&mut codecs, &w, max_rejects, &clock),
            None => Ok(()),
        },
        ExecEvent::Batch(route, items) => run_batch_sim(
            &spec,
            route,
            items,
            &mut sessions,
            &mut codecs,
            &mut encoder,
            &mut arena,
            &metrics,
            &cfg.clock,
            max_rejects,
        ),
    });
}

/// Sim-backend batch execution: real session stacking and metrics, modelled
/// compute time, and (with `encode`) real compiled-shader encodes. All
/// per-batch state (observation rows, actions, reply frames) lives in the
/// arena — the per-item `HashMap` action scatter is gone.
#[allow(clippy::too_many_arguments)]
fn run_batch_sim(
    spec: &SimSpec,
    route: Route,
    items: &[super::batcher::Item<Work>],
    sessions: &mut SessionManager,
    codecs: &mut Decoders,
    encoder: &mut SimEncoder,
    arena: &mut BatchArena,
    metrics: &Metrics,
    clock: &ClockHandle,
    max_rejects: u32,
) -> Result<()> {
    let n = items.len();
    let dequeue = clock.now();

    // raw frames still flow through the per-client frame stack so shard-local
    // session state stays meaningful under the fleet gateway (outside the
    // modelled window, exactly as before this PR) — stacked observations
    // now land directly in arena batch rows. Codec frames run the real
    // decoder so the delta chain (and its need-keyframe feedback) behaves
    // identically on Sim and PJRT shards.
    let t_pack = clock.now();
    let feat_dim = items
        .iter()
        .map(|i| match &i.work.payload {
            Payload::RawRgba { x, .. } => 9 * (*x as usize) * (*x as usize),
            Payload::Features { .. } => 0,
            Payload::FeaturesV2(f) => f.feat_len(),
            // experience frames never enter the batcher (executor_loop
            // dispatches them in ingress order)
            Payload::Experience(_) => 0,
        })
        .max()
        .unwrap_or(0);
    // populate the queue-wait scratch only after `begin` (which clears it):
    // the reply loop indexes it per item
    arena.begin(0, n, feat_dim);
    arena
        .queue_waits
        .extend(items.iter().map(|i| dequeue.duration_since(i.work.received)));
    encoder.to_encode.clear();
    for (i, item) in items.iter().enumerate() {
        match &item.work.payload {
            Payload::RawRgba { x, data } => {
                let x = *x as usize;
                let row = arena.row_mut(i);
                sessions.ingest_rgba_into(item.work.client, x, data, &mut row[..9 * x * x])?;
                // a zero-sized frame has nothing to encode (and a 0-pixel
                // plan would be degenerate): fall back to the zero-action
                // reply
                if spec.encode && x > 0 {
                    encoder.to_encode.push((i, x));
                }
            }
            Payload::Features { .. } | Payload::Experience(_) => {}
            Payload::FeaturesV2(f) => {
                let flen = f.feat_len();
                let row = arena.row_mut(i);
                let failed = codecs.decode_into(item.work.client, f, &mut row[..flen]).is_err();
                if failed {
                    row[..flen].fill(0.0);
                    arena.need_key[i] = true;
                    quarantine_codec_abuser(codecs, &item.work, max_rejects);
                }
            }
        }
    }
    let packed = clock.now();
    let pack_time = packed.duration_since(t_pack);

    // the modelled accelerator: launch overhead + linear per-item cost.
    // Real compiled-shader encodes run inside the window and only their
    // own time is deducted, so encode:false batches sleep the full budget.
    let t_exec = clock.now();
    arena.begin_actions(n, spec.action_dim);
    // take the worklist so the encoder stays borrowable inside the loop
    // (mem::take swaps in an empty Vec — no allocation either way)
    let to_encode = std::mem::take(&mut encoder.to_encode);
    for &(i, x) in &to_encode {
        let (row, act) = arena.row_and_action(i, spec.action_dim);
        encoder.encode_into(x, &row[..9 * x * x], act)?;
    }
    encoder.to_encode = to_encode;
    let modelled = spec.fixed + spec.per_item * n as u32;
    let spent = clock.now().duration_since(t_exec);
    if modelled > spent {
        clock.sleep(modelled - spent);
    }
    let executed = clock.now();
    let exec_time = executed.duration_since(t_exec);

    let done = clock.now();
    arena.services.clear();
    arena
        .services
        .extend(items.iter().map(|i| done.duration_since(i.work.received)));
    metrics.record_batch(
        route,
        n,
        0,
        pack_time,
        &arena.queue_waits,
        exec_time,
        &arena.services,
    );

    for (i, item) in items.iter().enumerate() {
        let a0 = i * spec.action_dim;
        encode_reply(
            &item.work,
            arena.need_key[i],
            arena.queue_waits[i],
            &arena.actions[a0..a0 + spec.action_dim],
            &mut arena.frame,
        );
        stamp_reply_trace(
            &item.work,
            dequeue,
            packed,
            executed,
            clock,
            &mut arena.frame,
            &mut arena.traces,
        );
        let mut w = item.work.reply.lock().unwrap();
        if let Err(e) = write_frame(&mut *w, &arena.frame) {
            debug!("reply to client {}: {e}", item.work.client);
        }
    }
    metrics.record_traces(&arena.traces);
    Ok(())
}

/// Complete a traced item's server-side span and echo it on the reply:
/// dequeue/pack/execute come from the batch's shared instants, the reply
/// hop is stamped now, the trailer is appended to the pooled reply frame
/// (re-sealing its length prefix), and the span is retained in the
/// arena's per-batch scratch for the metrics flight recorder. Untraced
/// items return immediately.
fn stamp_reply_trace(
    work: &Work,
    dequeue: Instant,
    packed: Instant,
    executed: Instant,
    clock: &ClockHandle,
    frame: &mut Vec<u8>,
    traces: &mut Vec<TraceCtx>,
) {
    let Some(mut t) = work.trace else { return };
    t.stamp(trace::STAGE_DEQUEUE, trace::ns_since_epoch(dequeue));
    t.stamp(trace::STAGE_PACK, trace::ns_since_epoch(packed));
    t.stamp(trace::STAGE_EXECUTE, trace::ns_since_epoch(executed));
    t.stamp(trace::STAGE_REPLY, trace::now_ns(clock));
    trace::append_trace(frame, &t);
    traces.push(t);
}

/// Encode one reply into the pooled `frame`: v1 responses for v1
/// payloads, v2 responses (codec feedback: echoed seq, need-keyframe
/// verdict, queue wait) for codec payloads. An undecodable codec frame
/// replies with an empty action plus the re-key demand, mirroring the
/// back-pressure rejection shape.
fn encode_reply(
    work: &Work,
    need_key: bool,
    queue_wait: Duration,
    action: &[f32],
    frame: &mut Vec<u8>,
) {
    match &work.payload {
        Payload::FeaturesV2(f) => {
            let (flags, act): (u8, &[f32]) =
                if need_key { (RESP_FLAG_NEED_KEYFRAME, &[]) } else { (0, action) };
            let qw = queue_wait.as_micros().min(u32::MAX as u128) as u32;
            encode_response_v2_into(work.client, work.id, f.seq, flags, qw, act, frame);
        }
        _ => encode_response_into(work.client, work.id, action, frame),
    }
}

#[allow(clippy::too_many_arguments)]
fn run_batch(
    rt: &Runtime,
    exec: &mut RouteExec,
    route: Route,
    items: &[super::batcher::Item<Work>],
    sessions: &mut SessionManager,
    codecs: &mut Decoders,
    arena: &mut BatchArena,
    metrics: &Metrics,
    clock: &ClockHandle,
    max_rejects: u32,
) -> Result<()> {
    let n = items.len();
    let b = pick_batch(n, &exec.ladder);
    let dequeue = clock.now();

    // compile-on-first-use per ladder entry
    if !exec.exes.contains_key(&b) {
        let exe = rt.load(&format!("{}_b{b}", exec.prefix))?;
        exec.exes.insert(b, exe);
    }
    let exe = exec.exes[&b].clone();

    // fused dequantise-and-pack: each request's features land directly in
    // its arena batch row (padding rows are zeroed by `begin`) — no
    // per-request `Vec<f32>` anywhere on this path
    let in_spec = &exe.spec.inputs[1];
    let per_item: usize = in_spec.shape[1..].iter().product();
    let t_pack = clock.now();
    // populate the queue-wait scratch only after `begin` (which clears it):
    // the reply loop indexes it per item
    arena.begin(n, b, per_item);
    arena
        .queue_waits
        .extend(items.iter().map(|i| dequeue.duration_since(i.work.received)));
    for (i, item) in items.iter().enumerate() {
        let row = arena.row_mut(i);
        let failed = match &item.work.payload {
            Payload::RawRgba { x, data: rgba } => {
                sessions.ingest_rgba_into(item.work.client, *x as usize, rgba, row)?;
                false
            }
            Payload::Features { scale, data: q, .. } => {
                anyhow::ensure!(q.len() == per_item, "feat len {} != {per_item}", q.len());
                dequantize_features_into(*scale, q, row);
                false
            }
            Payload::FeaturesV2(f) => {
                // a frame this executor cannot decode (chain break after a
                // reconnect, stale base, corrupt payload, wrong geometry)
                // must not kill the batch: zero the row, flag the item, and
                // let the v2 reply demand a keyframe
                if f.feat_len() == per_item {
                    match codecs.decode_into(item.work.client, f, row) {
                        Ok(()) => false,
                        Err(e) => {
                            debug!("codec reject for client {}: {e:#}", item.work.client);
                            quarantine_codec_abuser(codecs, &item.work, max_rejects);
                            row.fill(0.0);
                            true
                        }
                    }
                } else {
                    debug!(
                        "codec frame geometry {} != {per_item} from client {}",
                        f.feat_len(),
                        item.work.client
                    );
                    row.fill(0.0);
                    true
                }
            }
            // never batched (executor_loop handles experience directly)
            Payload::Experience(_) => false,
        };
        if failed {
            arena.need_key[i] = true;
        }
    }
    let packed = clock.now();
    let pack_time = packed.duration_since(t_pack);

    // execute with device-resident params; the arena matrix is staged
    // directly and outputs decode into the route's pooled `Value`s
    let t_exec = clock.now();
    let batch_dev = rt.to_device_f32(&in_spec.shape, arena.matrix())?;
    exe.run_device_into(&[&exec.params, &batch_dev], &mut exec.outs)?;
    let executed = clock.now();
    let exec_time = executed.duration_since(t_exec);

    let actions = exec.outs[0].as_f32()?;
    let adim = exe.spec.outputs[0].shape[1];

    // record metrics BEFORE writing responses: a client that just received
    // its action must observe its request in the metrics snapshot
    let done = clock.now();
    arena.services.clear();
    arena
        .services
        .extend(items.iter().map(|i| done.duration_since(i.work.received)));
    metrics.record_batch(
        route,
        n,
        b - n,
        pack_time,
        &arena.queue_waits,
        exec_time,
        &arena.services,
    );

    // respond from the contiguous action matrix through the pooled reply
    // frame — no per-action `.to_vec()`, no per-reply encode allocation
    for (i, item) in items.iter().enumerate() {
        encode_reply(
            &item.work,
            arena.need_key[i],
            arena.queue_waits[i],
            &actions[i * adim..(i + 1) * adim],
            &mut arena.frame,
        );
        stamp_reply_trace(
            &item.work,
            dequeue,
            packed,
            executed,
            clock,
            &mut arena.frame,
            &mut arena.traces,
        );
        let mut w = item.work.reply.lock().unwrap();
        if let Err(e) = write_frame(&mut *w, &arena.frame) {
            debug!("reply to client {}: {e}", item.work.client);
        }
    }
    metrics.record_traces(&arena.traces);
    Ok(())
}

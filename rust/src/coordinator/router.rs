//! Request routing: map an incoming request to the executable family that
//! serves it, and pick a batch size from the compiled ladder.
//!
//! Two routes exist (paper §4.3/4.4):
//!   * `Full`  — server-only pipeline: raw RGBA observation in, the whole
//!     Full-CNN policy runs server-side;
//!   * `Split` — split-policy pipeline: the device already ran the MiniConv
//!     encoder; only the head (projection + actor MLP) runs server-side.

use crate::net::framing::Payload;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Route {
    /// server-only: full policy over raw observations
    Full,
    /// split: head over transmitted features
    Split,
}

impl Route {
    /// Every route, in dense-index order (see [`Route::index`]).
    pub const ALL: [Route; 2] = [Route::Full, Route::Split];

    pub fn of(payload: &Payload) -> Route {
        match payload {
            Payload::RawRgba { .. } => Route::Full,
            Payload::Features { .. } | Payload::FeaturesV2(_) | Payload::Experience(_) => {
                Route::Split
            }
        }
    }

    /// Dense index for per-route arrays (batcher queues, pooled scratch).
    pub fn index(self) -> usize {
        match self {
            Route::Full => 0,
            Route::Split => 1,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Route::Full => "server-only",
            Route::Split => "split",
        }
    }
}

/// Pick the smallest ladder entry >= n, or the largest available (callers
/// then split the batch). Ladder must be sorted ascending.
pub fn pick_batch(n: usize, ladder: &[usize]) -> usize {
    assert!(!ladder.is_empty(), "empty batch ladder");
    for &b in ladder {
        if b >= n {
            return b;
        }
    }
    *ladder.last().unwrap()
}

/// Split `n` items into chunks shaped by the ladder (greedy largest-first),
/// e.g. n=37, ladder `[1,2,4,8,16,32]` -> `[32, 4, 1]`.
pub fn chunk_batches(mut n: usize, ladder: &[usize]) -> Vec<usize> {
    assert!(!ladder.is_empty());
    let mut out = Vec::new();
    while n > 0 {
        let max = *ladder.last().unwrap();
        if n >= max {
            out.push(max);
            n -= max;
        } else {
            let b = pick_batch(n, ladder);
            out.push(b);
            n = n.saturating_sub(b);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const LADDER: &[usize] = &[1, 2, 4, 8, 16, 32];

    #[test]
    fn route_of_payload() {
        assert_eq!(
            Route::of(&Payload::RawRgba { x: 84, data: vec![] }),
            Route::Full
        );
        assert_eq!(
            Route::of(&Payload::Features { c: 4, h: 11, w: 11, scale: 1.0, data: vec![] }),
            Route::Split
        );
        assert_eq!(
            Route::of(&Payload::FeaturesV2(crate::net::framing::FeatureFrame {
                c: 4,
                h: 11,
                w: 11,
                codec: 1,
                flags: 1,
                qmax: 255,
                seq: 1,
                scale: 1.0,
                data: vec![],
            })),
            Route::Split
        );
        assert_eq!(
            Route::of(&Payload::Experience(crate::net::framing::ExperienceFrame {
                feat: crate::net::framing::FeatureFrame {
                    c: 3,
                    h: 1,
                    w: 1,
                    codec: 1,
                    flags: 1,
                    qmax: 255,
                    seq: 1,
                    scale: 1.0,
                    data: vec![],
                },
                ep: 0,
                step: 0,
                flags: 0,
                reward: 0.0,
            })),
            Route::Split
        );
        assert_eq!(Route::Full.name(), "server-only");
    }

    #[test]
    fn dense_indices_cover_all_routes() {
        let mut seen = [false; 2];
        for r in Route::ALL {
            seen[r.index()] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn pick_smallest_covering() {
        assert_eq!(pick_batch(1, LADDER), 1);
        assert_eq!(pick_batch(3, LADDER), 4);
        assert_eq!(pick_batch(8, LADDER), 8);
        assert_eq!(pick_batch(9, LADDER), 16);
        assert_eq!(pick_batch(33, LADDER), 32); // capped at max
    }

    #[test]
    fn chunking_covers_all_items() {
        for n in 1..=100 {
            let chunks = chunk_batches(n, LADDER);
            let total: usize = chunks.iter().sum();
            assert!(total >= n, "n={n} chunks={chunks:?}");
            // padding waste is bounded by the ladder geometry (< 2x)
            assert!(total < 2 * n.max(1), "wasteful: n={n} chunks={chunks:?}");
        }
    }

    #[test]
    fn chunking_prefers_large_batches() {
        assert_eq!(chunk_batches(37, LADDER), vec![32, 8]);
        assert_eq!(chunk_batches(64, LADDER), vec![32, 32]);
        assert_eq!(chunk_batches(5, LADDER), vec![8]);
    }

    #[test]
    #[should_panic(expected = "empty batch ladder")]
    fn empty_ladder_panics() {
        pick_batch(1, &[]);
    }
}

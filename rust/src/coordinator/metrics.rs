//! Serving metrics: per-route latency histograms and counters, shared
//! between the executor thread and reporters via a mutex (updates are
//! O(1) bucket increments; contention is negligible at our request rates).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::trace::{Ring, TraceCtx};
use crate::util::stats::LatencyHist;

use super::router::Route;

/// Capacity of the per-server trace flight recorder (DESIGN.md §12):
/// enough to hold the recent tail at serving rates without the ring
/// itself becoming a memory consumer.
pub const TRACE_RING_CAP: usize = 1024;

#[derive(Debug, Default, Clone)]
pub struct RouteMetrics {
    pub requests: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub padded_slots: u64,
    /// server-side time: dequeue -> response written
    pub service: LatencyHist,
    /// queue wait: enqueue -> dequeue
    pub queue_wait: LatencyHist,
    /// batch assembly: fused dequantise/ingest pack into the arena matrix
    /// (one sample per batch)
    pub pack: LatencyHist,
    /// pure model execution time
    pub execute: LatencyHist,
}

impl RouteMetrics {
    fn new() -> Self {
        RouteMetrics {
            service: LatencyHist::new(),
            queue_wait: LatencyHist::new(),
            pack: LatencyHist::new(),
            execute: LatencyHist::new(),
            ..Default::default()
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        }
    }

    /// Fraction of executed slots that were padding.
    pub fn padding_ratio(&self) -> f64 {
        let total = self.batched_items + self.padded_slots;
        if total == 0 {
            0.0
        } else {
            self.padded_slots as f64 / total as f64
        }
    }

    /// Fold another server's counters and histograms into this one. Used by
    /// the fleet aggregator: histograms merge bucket-wise, so fleet-level
    /// percentiles come from one combined distribution — never from
    /// averaging per-shard percentiles.
    pub fn merge(&mut self, other: &RouteMetrics) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.batched_items += other.batched_items;
        self.padded_slots += other.padded_slots;
        self.service.merge(&other.service);
        self.queue_wait.merge(&other.queue_wait);
        self.pack.merge(&other.pack);
        self.execute.merge(&other.execute);
    }
}

#[derive(Debug, Default, Clone)]
pub struct MetricsInner {
    pub full: RouteMetrics,
    pub split: RouteMetrics,
    pub dropped: u64,
}

impl MetricsInner {
    pub fn route(&mut self, r: Route) -> &mut RouteMetrics {
        match r {
            Route::Full => &mut self.full,
            Route::Split => &mut self.split,
        }
    }

    pub fn route_ref(&self, r: Route) -> &RouteMetrics {
        match r {
            Route::Full => &self.full,
            Route::Split => &self.split,
        }
    }

    /// Fold another server's snapshot into this one (both routes + drops).
    pub fn merge(&mut self, other: &MetricsInner) {
        self.full.merge(&other.full);
        self.split.merge(&other.split);
        self.dropped += other.dropped;
    }
}

/// Shared handle. Alongside the histograms it carries the server-side
/// trace flight recorder (DESIGN.md §12): a bounded [`Ring`] of the most
/// recent per-decision spans as stamped through the reply hop, recorded
/// once per batch (one lock, no per-item locking) on traced sessions.
#[derive(Debug, Clone)]
pub struct Metrics {
    inner: Arc<Mutex<MetricsInner>>,
    traces: Arc<Mutex<Ring>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            inner: Arc::new(Mutex::new(MetricsInner {
                full: RouteMetrics::new(),
                split: RouteMetrics::new(),
                dropped: 0,
            })),
            traces: Arc::new(Mutex::new(Ring::with_capacity(TRACE_RING_CAP))),
        }
    }

    /// Record one batch's server-side spans into the flight recorder
    /// (no-op for empty batches; one lock per batch).
    pub fn record_traces(&self, spans: &[TraceCtx]) {
        if spans.is_empty() {
            return;
        }
        let mut r = self.traces.lock().unwrap();
        for s in spans {
            r.push(*s);
        }
    }

    /// Snapshot of the retained spans, oldest first.
    pub fn traces(&self) -> Vec<TraceCtx> {
        self.traces.lock().unwrap().to_vec()
    }

    /// The `n` slowest retained spans (exemplar dump feed).
    pub fn trace_exemplars(&self, n: usize) -> Vec<TraceCtx> {
        self.traces.lock().unwrap().slowest(n)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn record_batch(
        &self,
        route: Route,
        n_items: usize,
        padded: usize,
        pack: Duration,
        queue_waits: &[Duration],
        execute: Duration,
        service: &[Duration],
    ) {
        let mut m = self.inner.lock().unwrap();
        let rm = m.route(route);
        rm.requests += n_items as u64;
        rm.batches += 1;
        rm.batched_items += n_items as u64;
        rm.padded_slots += padded as u64;
        rm.pack.record(pack);
        rm.execute.record(execute);
        for d in queue_waits {
            rm.queue_wait.record(*d);
        }
        for d in service {
            rm.service.record(*d);
        }
    }

    pub fn add_dropped(&self, n: u64) {
        self.inner.lock().unwrap().dropped += n;
    }

    pub fn snapshot(&self) -> MetricsInner {
        self.inner.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_recording_accumulates() {
        let m = Metrics::new();
        m.record_batch(
            Route::Split,
            3,
            1,
            Duration::from_micros(40),
            &[Duration::from_millis(1); 3],
            Duration::from_millis(2),
            &[Duration::from_millis(5); 3],
        );
        m.record_batch(
            Route::Split,
            5,
            3,
            Duration::from_micros(40),
            &[Duration::from_millis(1); 5],
            Duration::from_millis(2),
            &[Duration::from_millis(9); 5],
        );
        let s = m.snapshot();
        assert_eq!(s.split.requests, 8);
        assert_eq!(s.split.batches, 2);
        assert!((s.split.mean_batch() - 4.0).abs() < 1e-9);
        assert!((s.split.padding_ratio() - 4.0 / 12.0).abs() < 1e-9);
        assert_eq!(s.split.service.count(), 8);
        // pack records one sample per batch
        assert_eq!(s.split.pack.count(), 2);
        assert_eq!(s.full.requests, 0);
    }

    #[test]
    fn p95_reflects_slow_tail() {
        let m = Metrics::new();
        for i in 0..100 {
            let ms = if i < 95 { 10 } else { 200 };
            m.record_batch(
                Route::Full,
                1,
                0,
                Duration::from_micros(5),
                &[Duration::from_millis(1)],
                Duration::from_millis(1),
                &[Duration::from_millis(ms)],
            );
        }
        let s = m.snapshot();
        let p95 = s.full.service.quantile_ns(0.95) / 1e6;
        assert!(p95 > 9.0, "p95={p95}ms");
        let p99 = s.full.service.quantile_ns(0.99) / 1e6;
        assert!(p99 > 150.0, "p99={p99}ms");
    }

    #[test]
    fn trace_ring_is_shared_bounded_and_sorted_by_span_length() {
        use crate::trace::{STAGE_ENQUEUE, STAGE_REPLY};
        let m = Metrics::new();
        let m2 = m.clone();
        let span = |id: u64, len: u64| {
            let mut t = TraceCtx::mint(id, 100);
            t.stamp(STAGE_ENQUEUE, 110);
            t.stamp(STAGE_REPLY, 100 + len);
            t
        };
        m2.record_traces(&[span(1, 50), span(2, 500), span(3, 5)]);
        m2.record_traces(&[]); // no-op
        assert_eq!(m.traces().len(), 3, "clones share the recorder");
        let top = m.trace_exemplars(2);
        assert_eq!(top.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 1]);
        // bounded: the ring never exceeds its capacity
        for i in 0..(TRACE_RING_CAP as u64 + 100) {
            m.record_traces(&[span(i + 10, i)]);
        }
        assert_eq!(m.traces().len(), TRACE_RING_CAP);
    }

    #[test]
    fn clone_shares_state() {
        let m = Metrics::new();
        let m2 = m.clone();
        m2.add_dropped(3);
        assert_eq!(m.snapshot().dropped, 3);
    }

    /// Record the same batches on (a) two shard-local Metrics that are then
    /// merged and (b) one combined Metrics; every counter and histogram
    /// quantile must agree exactly.
    #[test]
    fn merge_equals_single_combined_recorder() {
        let shard_a = Metrics::new();
        let shard_b = Metrics::new();
        let combined = Metrics::new();
        let record = |m: &Metrics, route, n: usize, ms: u64| {
            m.record_batch(
                route,
                n,
                0,
                Duration::from_micros(ms),
                &vec![Duration::from_millis(1); n],
                Duration::from_millis(2),
                &vec![Duration::from_millis(ms); n],
            );
        };
        // shard A fast, shard B slow — the regime where averaging per-shard
        // percentiles would lie
        for _ in 0..50 {
            record(&shard_a, Route::Split, 2, 5);
            record(&combined, Route::Split, 2, 5);
        }
        for _ in 0..10 {
            record(&shard_b, Route::Split, 1, 400);
            record(&combined, Route::Split, 1, 400);
        }
        record(&shard_b, Route::Full, 3, 7);
        record(&combined, Route::Full, 3, 7);
        shard_b.add_dropped(2);
        combined.add_dropped(2);

        let mut merged = shard_a.snapshot();
        merged.merge(&shard_b.snapshot());
        let want = combined.snapshot();

        assert_eq!(merged.split.requests, want.split.requests);
        assert_eq!(merged.split.batches, want.split.batches);
        assert_eq!(merged.full.requests, want.full.requests);
        assert_eq!(merged.dropped, want.dropped);
        assert_eq!(merged.split.service.count(), want.split.service.count());
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(
                merged.split.service.quantile_ns(q),
                want.split.service.quantile_ns(q),
                "quantile {q} diverged after merge"
            );
        }
        // and the merged p99 sees shard B's slow tail
        assert!(merged.split.service.quantile_ns(0.99) > 300e6);
    }
}

//! Serving metrics: per-route latency histograms and counters, shared
//! between the executor thread and reporters via a mutex (updates are
//! O(1) bucket increments; contention is negligible at our request rates).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::stats::LatencyHist;

use super::router::Route;

#[derive(Debug, Default, Clone)]
pub struct RouteMetrics {
    pub requests: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub padded_slots: u64,
    /// server-side time: dequeue -> response written
    pub service: LatencyHist,
    /// queue wait: enqueue -> dequeue
    pub queue_wait: LatencyHist,
    /// pure model execution time
    pub execute: LatencyHist,
}

impl RouteMetrics {
    fn new() -> Self {
        RouteMetrics {
            service: LatencyHist::new(),
            queue_wait: LatencyHist::new(),
            execute: LatencyHist::new(),
            ..Default::default()
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        }
    }

    /// Fraction of executed slots that were padding.
    pub fn padding_ratio(&self) -> f64 {
        let total = self.batched_items + self.padded_slots;
        if total == 0 {
            0.0
        } else {
            self.padded_slots as f64 / total as f64
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct MetricsInner {
    pub full: RouteMetrics,
    pub split: RouteMetrics,
    pub dropped: u64,
}

impl MetricsInner {
    pub fn route(&mut self, r: Route) -> &mut RouteMetrics {
        match r {
            Route::Full => &mut self.full,
            Route::Split => &mut self.split,
        }
    }

    pub fn route_ref(&self, r: Route) -> &RouteMetrics {
        match r {
            Route::Full => &self.full,
            Route::Split => &self.split,
        }
    }
}

/// Shared handle.
#[derive(Debug, Clone, Default)]
pub struct Metrics(Arc<Mutex<MetricsInner>>);

impl Metrics {
    pub fn new() -> Metrics {
        Metrics(Arc::new(Mutex::new(MetricsInner {
            full: RouteMetrics::new(),
            split: RouteMetrics::new(),
            dropped: 0,
        })))
    }

    pub fn record_batch(
        &self,
        route: Route,
        n_items: usize,
        padded: usize,
        queue_waits: &[Duration],
        execute: Duration,
        service: &[Duration],
    ) {
        let mut m = self.0.lock().unwrap();
        let rm = m.route(route);
        rm.requests += n_items as u64;
        rm.batches += 1;
        rm.batched_items += n_items as u64;
        rm.padded_slots += padded as u64;
        rm.execute.record(execute);
        for d in queue_waits {
            rm.queue_wait.record(*d);
        }
        for d in service {
            rm.service.record(*d);
        }
    }

    pub fn add_dropped(&self, n: u64) {
        self.0.lock().unwrap().dropped += n;
    }

    pub fn snapshot(&self) -> MetricsInner {
        self.0.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_recording_accumulates() {
        let m = Metrics::new();
        m.record_batch(
            Route::Split,
            3,
            1,
            &[Duration::from_millis(1); 3],
            Duration::from_millis(2),
            &[Duration::from_millis(5); 3],
        );
        m.record_batch(
            Route::Split,
            5,
            3,
            &[Duration::from_millis(1); 5],
            Duration::from_millis(2),
            &[Duration::from_millis(9); 5],
        );
        let s = m.snapshot();
        assert_eq!(s.split.requests, 8);
        assert_eq!(s.split.batches, 2);
        assert!((s.split.mean_batch() - 4.0).abs() < 1e-9);
        assert!((s.split.padding_ratio() - 4.0 / 12.0).abs() < 1e-9);
        assert_eq!(s.split.service.count(), 8);
        assert_eq!(s.full.requests, 0);
    }

    #[test]
    fn p95_reflects_slow_tail() {
        let m = Metrics::new();
        for i in 0..100 {
            let ms = if i < 95 { 10 } else { 200 };
            m.record_batch(
                Route::Full,
                1,
                0,
                &[Duration::from_millis(1)],
                Duration::from_millis(1),
                &[Duration::from_millis(ms)],
            );
        }
        let s = m.snapshot();
        let p95 = s.full.service.quantile_ns(0.95) / 1e6;
        assert!(p95 > 9.0, "p95={p95}ms");
        let p99 = s.full.service.quantile_ns(0.99) / 1e6;
        assert!(p99 > 150.0, "p99={p99}ms");
    }

    #[test]
    fn clone_shares_state() {
        let m = Metrics::new();
        let m2 = m.clone();
        m2.add_dropped(3);
        assert_eq!(m.snapshot().dropped, 3);
    }
}

//! Dynamic batcher: accumulate same-route requests up to a maximum batch
//! size or a waiting-time budget, whichever comes first — the standard
//! serving trade-off between batching efficiency and queueing latency.
//!
//! The collector is pure logic over an abstract clock so the policy is unit
//! testable; the server thread feeds it from an mpsc channel. Because every
//! method takes its `Instant` from the caller, the same collector runs
//! unchanged under the simnet's virtual clock (`sim::SimClock` mints the
//! instants there) — the chaos scenarios batch with this exact code.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::router::Route;

/// A queued unit of work, generic in the payload the executor needs.
#[derive(Debug)]
pub struct Item<T> {
    pub route: Route,
    pub enqueued: Instant,
    pub work: T,
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// execute as soon as this many same-route items are waiting
    pub max_batch: usize,
    /// ... or when the oldest item has waited this long
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(3) }
    }
}

/// Per-route FIFO queues + batch-forming policy.
#[derive(Debug)]
pub struct BatchCollector<T> {
    policy: BatchPolicy,
    queues: [VecDeque<Item<T>>; 2],
    /// total items dropped due to the depth bound
    pub dropped: u64,
    /// per-route admission bound (back-pressure)
    pub max_depth: usize,
}

impl<T> BatchCollector<T> {
    pub fn new(policy: BatchPolicy, max_depth: usize) -> Self {
        BatchCollector {
            policy,
            queues: [VecDeque::new(), VecDeque::new()],
            dropped: 0,
            max_depth,
        }
    }

    /// Enqueue; on a saturated route the work is handed back (and a drop
    /// counted) so the caller can build its rejection reply from the
    /// returned item instead of cloning reply handles up front.
    pub fn push(&mut self, route: Route, work: T, now: Instant) -> Option<T> {
        let q = &mut self.queues[route.index()];
        if q.len() >= self.max_depth {
            self.dropped += 1;
            return Some(work);
        }
        q.push_back(Item { route, enqueued: now, work });
        None
    }

    pub fn depth(&self, route: Route) -> usize {
        self.queues[route.index()].len()
    }

    /// The policy this collector batches under.
    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty())
    }

    /// Would a batch be ready at `now`? Returns the route to serve.
    /// Ready when a route has >= max_batch items, or its oldest item has
    /// waited >= max_wait. Ties go to the route with the older head
    /// (FIFO fairness across routes).
    pub fn ready(&self, now: Instant) -> Option<Route> {
        let mut best: Option<(Route, Instant)> = None;
        for route in Route::ALL {
            let q = &self.queues[route.index()];
            if let Some(head) = q.front() {
                let full = q.len() >= self.policy.max_batch;
                let waited = now.duration_since(head.enqueued) >= self.policy.max_wait;
                if full || waited {
                    match best {
                        Some((_, t)) if t <= head.enqueued => {}
                        _ => best = Some((route, head.enqueued)),
                    }
                }
            }
        }
        best.map(|(r, _)| r)
    }

    /// If nothing is ready, how long until the oldest item's wait budget
    /// expires (None if all queues are empty) — the executor's sleep hint.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queues
            .iter()
            .filter_map(|q| q.front())
            .map(|head| {
                self.policy
                    .max_wait
                    .saturating_sub(now.duration_since(head.enqueued))
            })
            .min()
    }

    /// Drain up to max_batch items from a route's queue into
    /// caller-provided storage (cleared first; capacity is reused across
    /// batches — the executor's pooled batch buffer).
    pub fn take_into(&mut self, route: Route, out: &mut Vec<Item<T>>) {
        out.clear();
        let q = &mut self.queues[route.index()];
        let n = q.len().min(self.policy.max_batch);
        out.extend(q.drain(..n));
    }

    /// Take up to max_batch items from a route's queue (allocating
    /// convenience over [`BatchCollector::take_into`]).
    pub fn take(&mut self, route: Route) -> Vec<Item<T>> {
        let mut out = Vec::new();
        self.take_into(route, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn batch_fires_on_size() {
        let mut c = BatchCollector::new(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) },
            100,
        );
        let now = t0();
        for i in 0..3 {
            c.push(Route::Split, i, now);
            assert_eq!(c.ready(now), None, "fired early at {i}");
        }
        c.push(Route::Split, 3, now);
        assert_eq!(c.ready(now), Some(Route::Split));
        let batch = c.take(Route::Split);
        assert_eq!(batch.len(), 4);
        assert!(c.is_empty());
    }

    #[test]
    fn batch_fires_on_wait() {
        let mut c = BatchCollector::new(
            BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5) },
            100,
        );
        let now = t0();
        c.push(Route::Full, 0, now);
        assert_eq!(c.ready(now), None);
        let later = now + Duration::from_millis(6);
        assert_eq!(c.ready(later), Some(Route::Full));
    }

    #[test]
    fn fifo_across_routes_on_tie() {
        let mut c = BatchCollector::new(
            BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
            100,
        );
        let now = t0();
        c.push(Route::Split, 0, now);
        c.push(Route::Full, 1, now + Duration::from_millis(1));
        assert_eq!(c.ready(now + Duration::from_millis(2)), Some(Route::Split));
        c.take(Route::Split);
        assert_eq!(c.ready(now + Duration::from_millis(2)), Some(Route::Full));
    }

    #[test]
    fn backpressure_returns_rejected_work() {
        let mut c = BatchCollector::new(BatchPolicy::default(), 2);
        let now = t0();
        assert!(c.push(Route::Split, 0, now).is_none());
        assert!(c.push(Route::Split, 1, now).is_none());
        // the saturated push hands the work back for an explicit rejection
        assert_eq!(c.push(Route::Split, 2, now), Some(2));
        assert_eq!(c.dropped, 1);
        assert_eq!(c.depth(Route::Split), 2);
        // other route unaffected
        assert!(c.push(Route::Full, 3, now).is_none());
    }

    #[test]
    fn take_into_reuses_buffer_and_preserves_fifo() {
        let mut c = BatchCollector::new(
            BatchPolicy { max_batch: 3, max_wait: Duration::ZERO },
            100,
        );
        let now = t0();
        for i in 0..5 {
            c.push(Route::Full, i, now);
        }
        let mut batch = Vec::new();
        c.take_into(Route::Full, &mut batch);
        assert_eq!(batch.iter().map(|i| i.work).collect::<Vec<_>>(), vec![0, 1, 2]);
        let cap = batch.capacity();
        c.take_into(Route::Full, &mut batch);
        assert_eq!(batch.iter().map(|i| i.work).collect::<Vec<_>>(), vec![3, 4]);
        assert!(batch.capacity() >= cap, "drain-into must not shrink the pooled buffer");
        c.take_into(Route::Full, &mut batch);
        assert!(batch.is_empty());
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut c = BatchCollector::new(
            BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(10) },
            100,
        );
        let now = t0();
        assert_eq!(c.next_deadline(now), None);
        c.push(Route::Split, 0, now);
        let d = c.next_deadline(now + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6));
    }

    #[test]
    fn take_caps_at_max_batch() {
        let mut c = BatchCollector::new(
            BatchPolicy { max_batch: 3, max_wait: Duration::ZERO },
            100,
        );
        let now = t0();
        for i in 0..7 {
            c.push(Route::Full, i, now);
        }
        assert_eq!(c.take(Route::Full).len(), 3);
        assert_eq!(c.depth(Route::Full), 4);
    }

    #[test]
    fn prop_no_item_lost_or_duplicated() {
        check(100, |g| {
            let max_batch = g.usize(1, 8);
            let n = g.usize(1, 50);
            let mut c: BatchCollector<usize> = BatchCollector::new(
                BatchPolicy { max_batch, max_wait: Duration::ZERO },
                1000,
            );
            let now = t0();
            for i in 0..n {
                let route = if g.bool() { Route::Split } else { Route::Full };
                c.push(route, i, now);
            }
            let mut seen = Vec::new();
            let later = now + Duration::from_millis(1);
            while let Some(r) = c.ready(later) {
                for item in c.take(r) {
                    seen.push(item.work);
                }
            }
            seen.sort_unstable();
            prop_assert(
                seen == (0..n).collect::<Vec<_>>(),
                format!("lost/dup items: {seen:?}"),
            )
        });
    }

    #[test]
    fn prop_batches_respect_max_and_fifo() {
        check(100, |g| {
            let max_batch = g.usize(1, 16);
            let n = g.usize(1, 60);
            let mut c: BatchCollector<usize> = BatchCollector::new(
                BatchPolicy { max_batch, max_wait: Duration::ZERO },
                1000,
            );
            let now = t0();
            for i in 0..n {
                c.push(Route::Split, i, now);
            }
            let later = now + Duration::from_millis(1);
            let mut prev = None;
            while c.ready(later).is_some() {
                let b = c.take(Route::Split);
                prop_assert(b.len() <= max_batch, "batch too large")?;
                for item in &b {
                    if let Some(p) = prev {
                        prop_assert(item.work > p, "FIFO violated")?;
                    }
                    prev = Some(item.work);
                }
            }
            Ok(())
        });
    }
}

//! Pooled storage for the executor's ingest→batch→policy→reply hot path.
//!
//! One [`BatchArena`] lives as long as its executor thread; every
//! per-batch buffer on the request path draws from it, so steady-state
//! batches perform no heap allocation (DESIGN.md §5 has the lifetime
//! rules). The centrepiece is a contiguous row-major `[rows, feat_dim]`
//! batch matrix: the fused dequantise/ingest pack writes each request's
//! features directly into its row, and the policy executable consumes the
//! matrix without any intermediate per-request `Vec<f32>`.

use std::time::Duration;

use crate::trace::TraceCtx;

/// Pooled batch-assembly buffers owned by one executor thread.
///
/// Buffer lifetime rules (DESIGN.md §5):
///   * the arena outlives every batch; batches only *view* its storage;
///   * [`BatchArena::begin`] reshapes the matrix for the next batch and
///     zero-fills padding rows only — occupied rows are fully overwritten
///     by the pack loop, never trusted from the previous batch;
///   * a geometry change (different `feat_dim` or element count) zeroes
///     the whole matrix, since stale content would be laid out wrongly;
///   * scratch vectors (`queue_waits`, `services`, `actions`, `frame`)
///     are cleared per batch but keep their capacity forever.
#[derive(Debug, Default)]
pub struct BatchArena {
    /// contiguous row-major `[rows, feat_dim]` batch matrix
    matrix: Vec<f32>,
    feat_dim: usize,
    rows: usize,
    /// per-item queue-wait scratch for metrics
    pub queue_waits: Vec<Duration>,
    /// per-item service-time scratch for metrics
    pub services: Vec<Duration>,
    /// flat `[rows * action_dim]` batched policy output
    pub actions: Vec<f32>,
    /// per-item codec verdict: true when the item's feature frame failed
    /// to decode (chain break / stale base / corrupt payload) — its row is
    /// zeroed and its reply carries `RESP_FLAG_NEED_KEYFRAME`
    pub need_key: Vec<bool>,
    /// encoded reply-frame scratch (one reply at a time)
    pub frame: Vec<u8>,
    /// per-batch scratch of server-side spans for traced items (stamped
    /// through the reply hop, flushed to the metrics flight recorder once
    /// per batch — `TraceCtx` is `Copy`, so this never allocates at
    /// steady state)
    pub traces: Vec<TraceCtx>,
}

impl BatchArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Begin a batch: shape the matrix as `[rows, feat_dim]` and zero the
    /// rows at and beyond `used` (the padding slots the executable sees).
    /// Rows below `used` must each be fully overwritten by the caller's
    /// pack loop. Capacity is kept across batches; steady-state calls with
    /// a stable geometry never touch the heap.
    pub fn begin(&mut self, used: usize, rows: usize, feat_dim: usize) {
        assert!(used <= rows, "used {used} > rows {rows}");
        let elems = rows * feat_dim;
        if self.matrix.len() != elems || self.feat_dim != feat_dim {
            // geometry change: previous content has the wrong layout
            self.matrix.clear();
            self.matrix.resize(elems, 0.0);
        } else {
            self.matrix[used * feat_dim..].fill(0.0);
        }
        self.rows = rows;
        self.feat_dim = feat_dim;
        self.queue_waits.clear();
        self.services.clear();
        self.need_key.clear();
        self.need_key.resize(rows, false);
        self.traces.clear();
    }

    pub fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Mutable view of row `i` — the fused dequant/ingest pack target.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let d = self.feat_dim;
        &mut self.matrix[i * d..(i + 1) * d]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let d = self.feat_dim;
        &self.matrix[i * d..(i + 1) * d]
    }

    /// The packed `[rows, feat_dim]` matrix (padding rows zeroed).
    pub fn matrix(&self) -> &[f32] {
        &self.matrix
    }

    /// Size the flat action buffer to `rows * action_dim`, zero-filled
    /// (items the policy skips reply with zero actions).
    pub fn begin_actions(&mut self, rows: usize, action_dim: usize) {
        self.actions.clear();
        self.actions.resize(rows * action_dim, 0.0);
    }

    /// Disjoint (input row, action row) views for in-place policy
    /// evaluation over the arena's own storage.
    pub fn row_and_action(&mut self, i: usize, action_dim: usize) -> (&[f32], &mut [f32]) {
        let d = self.feat_dim;
        (
            &self.matrix[i * d..(i + 1) * d],
            &mut self.actions[i * action_dim..(i + 1) * action_dim],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_pack_without_bleed_and_padding_is_zeroed() {
        let mut a = BatchArena::new();
        a.begin(2, 4, 3);
        a.row_mut(0).copy_from_slice(&[1.0, 2.0, 3.0]);
        a.row_mut(1).copy_from_slice(&[4.0, 5.0, 6.0]);
        assert_eq!(a.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(a.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(a.row(2), &[0.0; 3]);
        assert_eq!(a.row(3), &[0.0; 3]);
        assert_eq!(a.matrix().len(), 12);
    }

    #[test]
    fn stable_geometry_rezeroes_only_padding() {
        let mut a = BatchArena::new();
        a.begin(4, 4, 2);
        for i in 0..4 {
            a.row_mut(i).fill(9.0);
        }
        // next batch uses fewer rows: the now-padding rows must be zeroed
        a.begin(2, 4, 2);
        assert_eq!(a.row(2), &[0.0; 2]);
        assert_eq!(a.row(3), &[0.0; 2]);
        // occupied rows are the caller's to overwrite — stale content is
        // permitted there by contract
        a.row_mut(0).fill(1.0);
        a.row_mut(1).fill(2.0);
        assert_eq!(a.matrix(), &[1.0, 1.0, 2.0, 2.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn geometry_change_zeroes_everything() {
        let mut a = BatchArena::new();
        a.begin(2, 2, 4);
        for i in 0..2 {
            a.row_mut(i).fill(7.0);
        }
        // same element count, different feat_dim: full re-zero
        a.begin(0, 4, 2);
        assert!(a.matrix().iter().all(|&v| v == 0.0));
        assert_eq!(a.feat_dim(), 2);
        assert_eq!(a.rows(), 4);
    }

    #[test]
    fn need_key_scratch_resets_every_batch() {
        let mut a = BatchArena::new();
        a.begin(2, 4, 3);
        assert_eq!(a.need_key, vec![false; 4]);
        a.need_key[1] = true;
        a.begin(2, 2, 3);
        assert_eq!(a.need_key, vec![false; 2], "stale verdicts must not leak");
    }

    #[test]
    fn actions_are_zero_defaulted_and_disjoint_from_rows() {
        let mut a = BatchArena::new();
        a.begin(2, 2, 3);
        a.row_mut(0).copy_from_slice(&[1.0, 2.0, 3.0]);
        a.begin_actions(2, 2);
        assert_eq!(a.actions, &[0.0; 4]);
        let (row, act) = a.row_and_action(0, 2);
        act[0] = row[0] + row[1];
        act[1] = row[2];
        assert_eq!(a.actions, &[3.0, 3.0, 0.0, 0.0]);
        // row content untouched
        assert_eq!(a.row(0), &[1.0, 2.0, 3.0]);
    }
}

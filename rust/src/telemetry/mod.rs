//! Telemetry: time-series recording for the sustained-load experiments
//! (Figs. 3/4 — per-frame time, temperature, power, RAM traces) and CSV
//! export so results are plottable outside the harness.

use std::collections::BTreeMap;

/// A named set of aligned time series.
#[derive(Debug, Default, Clone)]
pub struct Recorder {
    /// x axis (frame index or seconds)
    pub xs: Vec<f64>,
    pub series: BTreeMap<String, Vec<f64>>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    /// Record one sample row. Every column stays exactly `xs`-aligned no
    /// matter when a series first appears or which rows omit it: missing
    /// cells become explicit NaN gaps (CSV consumers see empty-ish cells,
    /// `downsample`/`to_csv` never index out of bounds). A key repeated
    /// within one row keeps its last value.
    pub fn record(&mut self, x: f64, values: &[(&str, f64)]) {
        self.xs.push(x);
        for (k, v) in values {
            let s = self.series.entry(k.to_string()).or_default();
            // backfill rows from before this series existed (and drop a
            // duplicate entry from this same row, so last-wins holds)
            s.resize(self.xs.len() - 1, f64::NAN);
            s.push(*v);
        }
        // series absent from this row get a gap, not a shorter column
        for s in self.series.values_mut() {
            if s.len() < self.xs.len() {
                s.push(f64::NAN);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&[f64]> {
        self.series.get(name).map(|v| v.as_slice())
    }

    /// Mean of a series over a trailing window (e.g. plateau detection).
    pub fn tail_mean(&self, name: &str, window: usize) -> Option<f64> {
        let s = self.series.get(name)?;
        if s.is_empty() {
            return None;
        }
        let tail = &s[s.len().saturating_sub(window)..];
        Some(tail.iter().sum::<f64>() / tail.len() as f64)
    }

    /// Mean of a leading window (e.g. pre-throttle behaviour).
    pub fn head_mean(&self, name: &str, window: usize) -> Option<f64> {
        let s = self.series.get(name)?;
        if s.is_empty() {
            return None;
        }
        let head = &s[..window.min(s.len())];
        Some(head.iter().sum::<f64>() / head.len() as f64)
    }

    /// Downsample to at most `n` points (stride sampling) for printing.
    pub fn downsample(&self, n: usize) -> Recorder {
        if self.xs.len() <= n || n == 0 {
            return self.clone();
        }
        let stride = self.xs.len().div_ceil(n);
        let mut out = Recorder::new();
        for i in (0..self.xs.len()).step_by(stride) {
            let row: Vec<(&str, f64)> =
                self.series.iter().map(|(k, v)| (k.as_str(), v[i])).collect();
            out.record(self.xs[i], &row);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("x");
        for k in self.series.keys() {
            out.push(',');
            out.push_str(k);
        }
        out.push('\n');
        for i in 0..self.xs.len() {
            out.push_str(&format!("{}", self.xs[i]));
            for v in self.series.values() {
                out.push_str(&format!(",{}", v[i]));
            }
            out.push('\n');
        }
        out
    }

    /// A compact sparkline-ish text rendering of one series.
    pub fn sparkline(&self, name: &str, width: usize) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let Some(s) = self.series.get(name) else {
            return String::new();
        };
        if s.is_empty() {
            return String::new();
        }
        let stride = (s.len().div_ceil(width)).max(1);
        let pts: Vec<f64> = s.chunks(stride).map(|c| c.iter().sum::<f64>() / c.len() as f64).collect();
        let lo = pts.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = pts.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        pts.iter()
            .map(|&v| {
                let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.5 };
                GLYPHS[((t * 7.0).round() as usize).min(7)]
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> Recorder {
        let mut r = Recorder::new();
        for i in 0..10 {
            r.record(i as f64, &[("t", i as f64 * 2.0), ("w", 1.0)]);
        }
        r
    }

    #[test]
    fn record_and_get() {
        let r = rec();
        assert_eq!(r.len(), 10);
        assert_eq!(r.get("t").unwrap()[3], 6.0);
        assert!(r.get("nope").is_none());
    }

    #[test]
    fn ragged_rows_are_backfilled_not_misaligned() {
        // regression: a series that appears late, one that vanishes, and a
        // duplicated key used to leave ragged columns that only a
        // debug_assert noticed — release builds then misindexed in
        // downsample/to_csv. Every column must stay xs-aligned, with NaN
        // marking the gaps.
        let mut r = Recorder::new();
        r.record(0.0, &[("a", 1.0)]);
        r.record(1.0, &[("a", 2.0), ("late", 10.0)]);
        r.record(2.0, &[("late", 20.0), ("dup", 7.0), ("dup", 8.0)]);
        assert_eq!(r.len(), 3);
        for (k, s) in &r.series {
            assert_eq!(s.len(), r.len(), "series {k} ragged");
        }
        assert_eq!(r.get("a").unwrap()[1], 2.0);
        assert!(r.get("a").unwrap()[2].is_nan(), "vanished series must gap");
        assert!(r.get("late").unwrap()[0].is_nan(), "late series must backfill");
        assert_eq!(r.get("late").unwrap()[2], 20.0);
        assert_eq!(r.get("dup").unwrap()[2], 8.0, "duplicate key is last-wins");
        // and the consumers that used to misindex now traverse cleanly
        assert_eq!(r.to_csv().lines().count(), 4);
        assert_eq!(r.downsample(2).series.len(), 3);
    }

    #[test]
    fn tail_and_head_means() {
        let r = rec();
        assert_eq!(r.tail_mean("t", 2).unwrap(), 17.0); // (16+18)/2
        assert_eq!(r.head_mean("t", 2).unwrap(), 1.0); // (0+2)/2
        assert_eq!(r.tail_mean("t", 100).unwrap(), 9.0);
    }

    #[test]
    fn downsample_preserves_columns() {
        let r = rec().downsample(3);
        assert!(r.len() <= 3 + 1);
        assert_eq!(r.series.len(), 2);
    }

    #[test]
    fn csv_layout() {
        let mut r = Recorder::new();
        r.record(0.0, &[("a", 1.0), ("b", 2.0)]);
        let csv = r.to_csv();
        assert!(csv.starts_with("x,a,b\n"));
        assert!(csv.contains("0,1,2"));
    }

    #[test]
    fn sparkline_monotone() {
        let r = rec();
        let s = r.sparkline("t", 10);
        assert_eq!(s.chars().count(), 10);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
    }
}

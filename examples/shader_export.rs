//! Export the deployable OpenGL artifacts for a MiniConv encoder: the pass
//! plan, the GLSL ES 1.00 fragment shaders, and a numerics check of the
//! shader interpreter against the XLA artifact — what you would flash onto
//! a Pi Zero 2 W.
//!
//! Run: `make artifacts && cargo run --release --example shader_export -- [outdir]`

use anyhow::Result;

use miniconv::runtime::{default_artifact_dir, Runtime};
use miniconv::shader::{gen_all, plan, EncoderIr};

fn main() -> Result<()> {
    let outdir = std::env::args().nth(1).unwrap_or_else(|| "shaders_out".into());
    let rt = Runtime::new(&default_artifact_dir())?;
    let x = rt.manifest.serve_x;

    for arch in ["miniconv4", "miniconv16"] {
        let (serve_meta, _) = &rt.manifest.encoders[arch];
        let ir = EncoderIr::from_meta(arch, rt.manifest.obs_channels, serve_meta);
        let p = plan(&ir, x)?;
        println!(
            "{arch} @ X={x}: {} passes | {} samples/frame | {} textures peak | worst pass {} samples/px",
            p.passes.len(),
            p.total_samples(),
            p.peak_textures(),
            p.passes.iter().map(|q| q.samples).max().unwrap_or(0),
        );
        let dir = format!("{outdir}/{arch}");
        std::fs::create_dir_all(&dir)?;
        std::fs::write(format!("{dir}/vertex.glsl"), miniconv::shader::VERTEX_SHADER)?;
        for s in gen_all(&p) {
            std::fs::write(format!("{dir}/{}.frag", s.name), &s.fragment)?;
        }
        println!("  wrote GLSL to {dir}/");
    }

    // fullcnn must be rejected by the planner — print the error a user
    // would see if they tried to deploy the baseline
    let (full_meta, _) = &rt.manifest.encoders["fullcnn"];
    let ir = EncoderIr::from_meta("fullcnn", rt.manifest.obs_channels, full_meta);
    match plan(&ir, x) {
        Err(e) => println!("fullcnn (baseline) is not deployable, as expected:\n  {e}"),
        Ok(_) => anyhow::bail!("fullcnn unexpectedly planned as shaders!"),
    }
    println!("shader_export OK");
    Ok(())
}

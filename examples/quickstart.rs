//! Quickstart: the whole split-policy pipeline in one process.
//!
//!   1. render a real Pendulum observation (100² → centre-crop 84²);
//!   2. run the MiniConv-4 encoder two ways — through the AOT Pallas/XLA
//!      artifact *and* through the OpenGL shader interpreter — and check
//!      they agree;
//!   3. quantise the features to the uint8 wire format;
//!   4. run the server-side head to get an action, and compare against the
//!      monolithic server-only policy path.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;

use miniconv::envs::{CropMode, Env, Pendulum, PixelPipeline};
use miniconv::net::{dequantize_features, quantize_features};
use miniconv::runtime::{default_artifact_dir, Runtime, Value};
use miniconv::shader::{pipeline_from_manifest, TextureFormat};
use miniconv::util::rng::Rng;

fn main() -> Result<()> {
    let rt = Runtime::new(&default_artifact_dir())?;
    let x = rt.manifest.serve_x;
    println!("== MiniConv quickstart (X={x}) ==");

    // 1. a real rendered observation
    let mut env = Pendulum::new();
    let mut rng = Rng::new(42);
    env.reset(&mut rng);
    let mut pipe = PixelPipeline::new(100, x, CropMode::Center);
    pipe.observe(&env, &mut rng);
    for _ in 0..3 {
        env.step(&[0.5]);
        pipe.observe(&env, &mut rng);
    }
    let obs = pipe.obs();
    println!("observation: 9x{x}x{x} = {} floats", obs.len());

    // 2a. device encoder via the AOT artifact (Pallas kernels under XLA)
    let enc = rt.load(&rt.manifest.serve_encoder("miniconv4"))?;
    let enc_params = rt.manifest.load_params("serve_enc_miniconv4")?;
    let feat_xla = enc.run(&[
        &Value::f32(&[enc_params.len()], enc_params),
        &Value::f32(&[1, 9, x, x], obs.clone()),
    ])?;
    let feat_xla = feat_xla[0].as_f32()?.to_vec();
    let s = x.div_ceil(8);
    println!("features: 4x{s}x{s} = {} floats (XLA artifact)", feat_xla.len());

    // 2b. the same encoder through the GL shader interpreter
    let (serve_meta, _) = &rt.manifest.encoders["miniconv4"];
    let shader = pipeline_from_manifest(
        &rt.manifest,
        "miniconv4",
        serve_meta,
        x,
        "serve_enc_miniconv4",
        TextureFormat::Float,
    )?;
    let feat_gl = shader.run(&pipe.obs_chw())?;
    let mut max_diff = 0.0f32;
    for (i, &v) in feat_xla.iter().enumerate() {
        let (c, rem) = (i / (s * s), i % (s * s));
        let d = (v - feat_gl.at(c, rem / s, rem % s)).abs();
        max_diff = max_diff.max(d);
    }
    println!("shader-vs-XLA max |diff| = {max_diff:.2e}  (must be < 1e-3)");
    assert!(max_diff < 1e-3);

    // 3. wire format: uint8 features (the paper's transmitted buffer)
    let (scale, q) = quantize_features(&feat_xla);
    println!(
        "wire: {} bytes (vs {} bytes raw RGBA) — {:.0}x smaller",
        q.len(),
        4 * x * x,
        (4 * x * x) as f64 / q.len() as f64
    );
    let feat_deq = dequantize_features(scale, &q);

    // 4. server head over the (dequantised) features
    let head = rt.load(&rt.manifest.serve_head("miniconv4", 1))?;
    let head_params = rt.manifest.load_params("serve_head_miniconv4")?;
    let act = head.run(&[
        &Value::f32(&[head_params.len()], head_params),
        &Value::f32(&[1, 4, s, s], feat_deq),
    ])?;
    println!("action (split pipeline)      : {:?}", act[0].as_f32()?);

    // server-only baseline for comparison
    let full = rt.load(&rt.manifest.serve_full(1))?;
    let full_params = rt.manifest.load_params("serve_full_fullcnn")?;
    let act_full = full.run(&[
        &Value::f32(&[full_params.len()], full_params),
        &Value::f32(&[1, 9, x, x], obs),
    ])?;
    println!("action (server-only baseline): {:?}", act_full[0].as_f32()?);
    println!("quickstart OK");
    Ok(())
}

//! Sharded serving demo: four coordinator shards behind the consistent-hash
//! gateway, driven by the simulated-device client fleet, then a live
//! connection-draining exercise.
//!
//! With AOT artifacts present the shards run the real PJRT backend and the
//! fleet serves both pipelines — pass `--codec delta` to run the split
//! fleet on the adaptive delta wire format (DESIGN.md §7) instead of the
//! flat u8 one. Without artifacts the Sim backend stands in so the whole
//! fleet path (gateway, hashing, draining, merged metrics) still runs end
//! to end over raw frames.
//!
//! Run: `cargo run --release --example serve_sharded -- [--codec flat|delta]`

use std::time::{Duration, Instant};

use anyhow::Result;

use miniconv::codec::CodecId;
use miniconv::coordinator::{
    run_fleet, Backend, BatchPolicy, ClientConfig, Route, ServerConfig, SimSpec,
};
use miniconv::fleet::{
    launch_local, AutoscaleConfig, FleetAutoscaleConfig, FleetConfig, ScaleAction, ShardId,
};
use miniconv::util::argparse::Parser;

fn main() -> Result<()> {
    let args = Parser::new("sharded serving demo")
        .opt("codec", "flat", "split-route feature codec: flat | delta")
        .flag("autoscale", "run the closed autoscaling loop (DESIGN.md §11) during the demo")
        .flag("trace", "negotiate CAP_TRACE fleet-wide and dump per-decision spans (DESIGN.md §12)")
        .opt("trace-out", "traces.jsonl", "JSONL span dump path (with --trace)")
        .parse();
    let traced = args.flag("trace");
    let codec = CodecId::parse(&args.str("codec"))?;
    let have_artifacts = miniconv::runtime::default_artifact_dir()
        .join("manifest.json")
        .exists();
    let backend = if have_artifacts {
        println!("artifacts found: shards run the real PJRT backend");
        Backend::Pjrt
    } else {
        println!("no artifacts: shards run the Sim backend (1 ms + 0.3 ms/item)");
        Backend::Sim(SimSpec {
            fixed: Duration::from_millis(1),
            per_item: Duration::from_micros(300),
            action_dim: 1,
            encode: true,
        })
    };

    println!("launching 4 shards + gateway…");
    let mut fleet = launch_local(FleetConfig {
        shards: 4,
        server: ServerConfig {
            policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(2) },
            backend,
            trace: traced,
            ..ServerConfig::default()
        },
        ..FleetConfig::default()
    })?;
    println!("gateway on {} fronting {} shards", fleet.addr(), fleet.n_shards());

    if args.flag("autoscale") {
        fleet.start_autoscale(FleetAutoscaleConfig {
            policy: AutoscaleConfig {
                min_shards: 2,
                max_shards: 6,
                queue_high_ns: 2_000_000,
                queue_low_ns: 200_000,
                shed_high: 0.05,
                shed_low: 0.005,
                confirm: 2,
                cooldown: 0.5,
            },
            interval: Duration::from_millis(100),
        })?;
        println!("autoscaler on: windowed samples every 100 ms, 2..=6 shards");
    }

    // with artifacts the fleet serves the split route, so the negotiated
    // codec actually carries the feature frames; the Sim fallback serves
    // raw frames (the codec negotiation is a split-route concern)
    let mode = if have_artifacts { Route::Split } else { Route::Full };
    println!("clients: {} route, {} codec", mode.name(), codec.name());
    let cfg = ClientConfig {
        mode,
        decisions: 30,
        obs_x: if have_artifacts { None } else { Some(24) },
        codec,
        trace: traced,
        ..ClientConfig::default()
    };
    let n_clients = 16;
    let t0 = Instant::now();
    let reports = run_fleet(fleet.addr(), n_clients, &cfg)?;
    let elapsed = t0.elapsed().as_secs_f64();
    let decisions: usize = reports.iter().map(|r| r.decisions).sum();
    println!(
        "\n{n_clients} clients × {} decisions in {elapsed:.2}s ({:.0} dec/s aggregate)",
        cfg.decisions,
        decisions as f64 / elapsed
    );
    let bytes: u64 = reports.iter().map(|r| r.bytes_sent).sum();
    println!(
        "wire: {bytes} B sent ({:.0} B/frame); codec: {} keyframes, {} deltas",
        bytes as f64 / decisions.max(1) as f64,
        reports.iter().map(|r| r.keyframes).sum::<u64>(),
        reports.iter().map(|r| r.deltas).sum::<u64>(),
    );

    // per-decision span export: the client-held spans are the complete
    // ones (every server hop echoed on the reply plus the client's own
    // recv stamp), so the dump and the exemplar table come from them
    if traced {
        let spans: Vec<miniconv::trace::TraceCtx> =
            reports.iter().flat_map(|r| r.traces.iter().copied()).collect();
        let mut jsonl = String::new();
        miniconv::trace::write_jsonl(&spans, &mut jsonl);
        let out = args.str("trace-out");
        std::fs::write(&out, jsonl)?;
        println!("\ntrace: {} spans -> {out}", spans.len());
        print!("{}", miniconv::trace::exemplar_table(&spans, 5));
    }

    fleet.snapshot().table(elapsed).print();

    let stats = fleet.gateway.stats();
    let mut placement: Vec<(ShardId, usize)> = fleet
        .shard_ids()
        .into_iter()
        .map(|id| (id, stats.assignments.values().filter(|&&s| s == id).count()))
        .collect();
    placement.sort();
    print!("session placement:");
    for (id, n) in &placement {
        print!("  {id}={n}");
    }
    println!("  (reassigned: {})", stats.reassigned);

    // connection draining: take the busiest shard out of rotation
    let (victim, _) = *placement.iter().max_by_key(|(_, n)| *n).expect("no shards");
    println!("\ndraining {victim} and running 8 fresh sessions…");
    fleet.gateway.drain(victim);
    let fresh: Vec<u32> = (1000..1008).collect();
    for &id in &fresh {
        miniconv::coordinator::run_client(fleet.addr(), id, &cfg)?;
    }
    let stats = fleet.gateway.stats();
    let leaked = fresh
        .iter()
        .filter(|&&id| stats.assignments.get(&id) == Some(&victim))
        .count();
    println!(
        "fresh sessions on the draining shard: {leaked} (want 0); drained: {}",
        fleet.gateway.drained(victim)
    );

    for (id, state, conns) in fleet.gateway.shard_states() {
        println!("  {id}: {} ({conns} live connections)", state.name());
    }

    if args.flag("autoscale") {
        // idle now: give the sampler a few empty windows so confirmed
        // down-pressure can park the surplus shards before we report
        fleet.wait_scale(Duration::from_secs(4), |ev| {
            let ups = ev.iter().filter(|e| e.action == ScaleAction::ScaleUp).count();
            let downs = ev.iter().filter(|e| e.action == ScaleAction::ScaleDown).count();
            !ev.is_empty() && downs >= ups
        });
        let events = fleet.scale_events();
        println!("\nautoscale events: {} ({} routable shards now)", events.len(), fleet.gateway.n_routable());
        for e in &events {
            println!(
                "  t={:.2}s {:?} {} (window p95 {:.2} ms, shed {:.3})",
                e.at,
                e.action,
                e.shard,
                e.sample.queue_p95_ns as f64 / 1e6,
                e.sample.shed_rate
            );
        }
    }

    fleet.shutdown();
    println!("\nfleet stopped cleanly");
    Ok(())
}

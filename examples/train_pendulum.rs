//! Train DDPG-from-pixels on Pendulum with the MiniConv-4 encoder, entirely
//! through the AOT train-step artifact (no Python at runtime), and log the
//! learning curve — the scaled-down counterpart of the paper's Table 4 row.
//!
//! Run: `make artifacts && cargo run --release --example train_pendulum -- [episodes]`

use anyhow::Result;

use miniconv::rl::{TrainConfig, Trainer};
use miniconv::runtime::{default_artifact_dir, Runtime};

fn main() -> Result<()> {
    let episodes: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    let rt = Runtime::new(&default_artifact_dir())?;
    let cfg = TrainConfig {
        episodes,
        warmup_steps: 300,
        train_freq: 8,
        log_every: 0,
        ..TrainConfig::default()
    };
    println!("training pendulum_miniconv4 for {episodes} episodes (DDPG, 9x36x36 pixels)…");
    let mut trainer = Trainer::new(&rt, "pendulum_miniconv4", cfg)?;

    let t0 = std::time::Instant::now();
    trainer.train()?;
    let dt = t0.elapsed().as_secs_f64();

    println!("\nepisode returns:");
    for (i, r) in trainer.report.stats.returns().iter().enumerate() {
        let bar_len = ((r + 1700.0) / 1700.0 * 40.0).clamp(0.0, 40.0) as usize;
        println!("  ep {:>3} {:>8.1} |{}", i + 1, r, "#".repeat(bar_len));
    }
    let s = &trainer.report.stats;
    println!(
        "\nBest {:.0}  Final {:.0}  Mean {:.0}  ({} env steps, {} updates, {:.1}s wall, {:.1} updates/s)",
        s.best(),
        s.final_100(),
        s.mean(),
        trainer.report.env_steps,
        trainer.report.updates,
        dt,
        trainer.report.updates as f64 / dt
    );
    if let Some((name, losses)) = trainer.report.metrics.first() {
        let head: f64 = losses.iter().take(10).map(|&x| x as f64).sum::<f64>() / 10f64.min(losses.len() as f64);
        let tail: f64 = losses.iter().rev().take(10).map(|&x| x as f64).sum::<f64>()
            / 10f64.min(losses.len() as f64);
        println!("{name}: first10 {head:.3} -> last10 {tail:.3}");
    }
    let eval = trainer.evaluate(2)?;
    println!("deterministic eval (2 episodes): {eval:.1}");
    println!("train_pendulum OK");
    Ok(())
}

//! End-to-end serving driver (the repo's headline validation run).
//!
//! Starts the real coordinator on loopback TCP, then drives fleets of
//! simulated edge devices (real Pendulum rendering, real shader-interpreter
//! encoding, Pi Zero 2 W timing model) through both pipelines at several
//! shaped bandwidths, reporting median/p95 decision latency and server
//! metrics — the wall-clock, task-scale (X=84) counterpart of Table 5,
//! plus a closed-loop throughput comparison.
//!
//! Run: `make artifacts && cargo run --release --example serve_fleet`
//! Recorded in EXPERIMENTS.md §End-to-end validation.

use std::time::Duration;

use anyhow::Result;

use miniconv::coordinator::{
    merged_latencies, run_fleet, serve, BatchPolicy, ClientConfig, Route, ServerConfig,
};
use miniconv::util::tables::Table;

fn main() -> Result<()> {
    let n_clients = 4;
    let decisions = 50;

    println!("starting coordinator (compiling serving artifacts)…");
    let server = serve(ServerConfig {
        policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(2) },
        ..ServerConfig::default()
    })?;
    println!("coordinator on {}", server.addr);

    let mut table = Table::new(
        "End-to-end decision latency, task scale X=84 (real coordinator, loopback TCP, shaped uplink)",
        &["bandwidth", "pipeline", "median (ms)", "p95 (ms)", "throughput (dec/s)"],
    );

    // Wire sizes at X=84: raw RGBA 28.2 kB vs features 484 B. The same
    // crossover as the paper's Table 5 appears at proportionally lower
    // bandwidths (raw ≈ 0.23 Mb/frame).
    for bw_mbps in [1.0f64, 2.0, 5.0, 25.0] {
        for (mode, name) in [(Route::Full, "server-only"), (Route::Split, "split")] {
            let cfg = ClientConfig {
                mode,
                decisions,
                shape_bps: Some(bw_mbps * 1e6),
                device: Some(miniconv::device::pi_zero_2w()),
                ..ClientConfig::default()
            };
            let reports = run_fleet(server.addr, n_clients, &cfg)?;
            let mut lat = merged_latencies(&reports);
            let hz: f64 = reports.iter().map(|r| r.achieved_hz()).sum();
            table.row(&[
                format!("{bw_mbps:.0} Mb/s"),
                name.into(),
                format!("{:.1}", lat.median() * 1e3),
                format!("{:.1}", lat.p95() * 1e3),
                format!("{hz:.1}"),
            ]);
        }
    }
    table.print();

    let m = server.metrics.snapshot();
    let mut t2 = Table::new(
        "server-side metrics",
        &["route", "requests", "batches", "mean batch", "exec p95 (ms)", "queue p95 (ms)"],
    );
    for (name, rm) in [("split", &m.split), ("server-only", &m.full)] {
        t2.row(&[
            name.into(),
            rm.requests.to_string(),
            rm.batches.to_string(),
            format!("{:.2}", rm.mean_batch()),
            format!("{:.2}", rm.execute.quantile_ns(0.95) / 1e6),
            format!("{:.2}", rm.queue_wait.quantile_ns(0.95) / 1e6),
        ]);
    }
    t2.print();

    server.shutdown();
    println!("\nserve_fleet OK");
    Ok(())
}

//! Edge-device sweep: per-frame encode time across the three simulated
//! boards and input sizes (Figure 2's workload), plus a sustained-load
//! mini-run showing the Jetson's thermal throttling and the Pi Zero's
//! GL-vs-CPU gap (Figures 3/4 at reduced length).
//!
//! Run: `cargo run --release --example edge_sweep`

use miniconv::device::all_devices;
use miniconv::experiments::{fig2_framesize, fig3_sustained};

fn main() {
    let sizes = [100usize, 200, 400, 500, 1000, 2000, 3000];
    println!("sweeping MiniConv-4 encode time across devices…");
    fig2_framesize(&all_devices(), &sizes, 100).print();

    println!("\nsustained load (1,500 frames; paper runs 5,000 — see `miniconv exp fig3`):");
    let (_, t) = fig3_sustained(1500);
    t.print();

    println!("\nedge_sweep OK");
}

"""AOT entry point: lower every model/train-step variant to HLO text and
emit ``artifacts/manifest.json`` + initial-parameter ``.bin`` files.

Run once by ``make artifacts``; Python never appears on the request path.

Interchange format is **HLO text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifact families
  serving (x = 84, paper's render-100 -> crop-84 pipeline):
    enc_<arch>_x84_b1          device-side encoder -> transmitted features
    head_<arch>_x84_b{1..32}   server-side head over features (batch ladder)
    full_fullcnn_x84_b{1..32}  server-only baseline policy over raw obs
  training (x = 36 "tiny" scale, DESIGN.md §2), per (task, encoder):
    <algo>_act[_det]_<task>_<arch>_b1
    <algo>_update_<task>_<arch>_b64

Usage: python -m compile.aot [--out-dir DIR] [--only REGEX] [--list]
"""

import argparse
import json
import math
import os
import re
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import rl
from .specs import (
    BATCH_LADDER,
    ENCODERS,
    OBS_CHANNELS,
    SERVE_CROP,
    TASKS,
    TINY_CROP,
    TRAIN_BATCH,
)

SEED = 0


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _spec_json(name, arr_spec):
    return {
        "name": name,
        "dtype": {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[
            jnp.dtype(arr_spec.dtype)
        ],
        "shape": list(arr_spec.shape),
    }


class Builder:
    def __init__(self, out_dir, only=None, list_only=False):
        self.out_dir = out_dir
        self.only = re.compile(only) if only else None
        self.list_only = list_only
        self.manifest = {
            "version": 1,
            "seed": SEED,
            "serve_x": SERVE_CROP,
            "tiny_x": TINY_CROP,
            "obs_channels": OBS_CHANNELS,
            "encoders": {},
            "artifacts": [],
            "params": [],
            "trainstates": [],
        }
        os.makedirs(out_dir, exist_ok=True)

    def want(self, name):
        return self.only is None or self.only.search(name)

    def artifact(self, name, fn, inputs, outputs, tags):
        """Lower fn at the given input specs and record it."""
        entry = {
            "name": name,
            "file": f"{name}.hlo.txt",
            "inputs": [_spec_json(n, s) for n, s in inputs],
            "outputs": [_spec_json(n, s) for n, s in outputs],
            "tags": tags,
        }
        self.manifest["artifacts"].append(entry)
        if self.list_only or not self.want(name):
            return
        t0 = time.time()
        lowered = jax.jit(fn).lower(*[s for _, s in inputs])
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, entry["file"])
        with open(path, "w") as f:
            f.write(text)
        print(f"  [{time.time() - t0:6.1f}s] {name}  ({len(text) / 1e6:.1f} MB)")

    def params_bin(self, name, arr):
        arr = np.asarray(arr, dtype="<f4")
        entry = {"name": name, "file": f"{name}.bin", "len": int(arr.size)}
        self.manifest["params"].append(entry)
        if not self.list_only:
            arr.tofile(os.path.join(self.out_dir, entry["file"]))
        return entry

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"wrote {path}: {len(self.manifest['artifacts'])} artifacts, "
              f"{len(self.manifest['params'])} param files, "
              f"{len(self.manifest['trainstates'])} trainstates")


# ---------------------------------------------------------------------------
# Encoder metadata (consumed by the Rust shader planner / param store)
# ---------------------------------------------------------------------------


def encoder_meta(spec, x):
    tmpl = M.enc_template(spec, x)
    c, h, w = spec.feat_shape(x)
    return {
        "kind": spec.kind,
        "shader_deployable": spec.shader_deployable,
        "layers": [
            {"cout": l.cout, "k": l.k, "stride": l.stride, "padding": l.padding}
            for l in spec.layers
        ],
        "dense": spec.dense,
        "n_stride2": spec.n_stride2(),
        "param_layout": [{"name": n, "shape": list(s)} for n, s in tmpl],
        "feat_shape": [c, h, w],
    }


# ---------------------------------------------------------------------------
# Serving artifacts
# ---------------------------------------------------------------------------


def build_serving(b: Builder):
    x = SERVE_CROP
    task = TASKS["pendulum"]  # the serving workload (paper's latency testbed)
    key = jax.random.PRNGKey(SEED)
    obs_shape = (OBS_CHANNELS, x, x)

    for arch in ("miniconv4", "miniconv16"):
        spec = ENCODERS[arch]
        et, ht = M.policy_templates(spec, x, task, "actor")
        key, sub = jax.random.split(key)
        flat = M.init_policy(sub, spec, x, task, "actor")
        enc_flat, head_flat = M.split_flat(flat, et, ht)
        b.params_bin(f"serve_enc_{arch}", enc_flat)
        b.params_bin(f"serve_head_{arch}", head_flat)

        c, h, w = spec.feat_shape(x)

        def enc_fn(p, obs, spec=spec):
            return (M.enc_apply(spec, p, obs),)

        b.artifact(
            f"enc_{arch}_x{x}_b1",
            enc_fn,
            [("params", sds((M.template_size(et),))), ("obs", sds((1, *obs_shape)))],
            [("feat", sds((1, c, h, w)))],
            {"kind": "encoder", "arch": arch, "x": x, "batch": 1},
        )

        def head_fn(p, feat, spec=spec, ht=ht):
            return (M.actor_head_apply(task, M.unpack(p, ht), feat),)

        for bb in BATCH_LADDER:
            b.artifact(
                f"head_{arch}_x{x}_b{bb}",
                head_fn,
                [
                    ("params", sds((M.template_size(ht),))),
                    ("feat", sds((bb, c, h, w))),
                ],
                [("act", sds((bb, task.action_dim)))],
                {"kind": "head", "arch": arch, "x": x, "batch": bb},
            )

    # Server-only baseline: the whole Full-CNN policy over raw observations.
    spec = ENCODERS["fullcnn"]
    key, sub = jax.random.split(key)
    flat = M.init_policy(sub, spec, x, task, "actor")
    b.params_bin("serve_full_fullcnn", flat)

    def full_fn(p, obs):
        return (M.actor_apply(spec, task, x, p, obs),)

    for bb in BATCH_LADDER:
        b.artifact(
            f"full_fullcnn_x{x}_b{bb}",
            full_fn,
            [
                ("params", sds((flat.shape[0],))),
                ("obs", sds((bb, *obs_shape))),
            ],
            [("act", sds((bb, task.action_dim)))],
            {"kind": "full", "arch": "fullcnn", "x": x, "batch": bb},
        )


# ---------------------------------------------------------------------------
# Training artifacts + initial train states
# ---------------------------------------------------------------------------


def _state_entry(b, run, name, arr=None, dtype="f32", shape=None):
    if arr is not None:
        p = b.params_bin(f"{run}_{name}", arr)
        return {"name": name, "dtype": "f32", "shape": [p["len"]], "file": p["file"]}
    return {"name": name, "dtype": dtype, "shape": shape or []}


def build_training_combo(b: Builder, task_name: str, arch: str):
    task = TASKS[task_name]
    spec = ENCODERS[arch]
    x = TINY_CROP
    bt = TRAIN_BATCH
    run = f"{task_name}_{arch}"
    key = jax.random.PRNGKey(SEED + hash(run) % 1000)
    obs_b1 = sds((1, OBS_CHANNELS, x, x))
    obs_bt = sds((bt, OBS_CHANNELS, x, x))
    adim = task.action_dim
    algo = task.algo

    state, batch_names, metrics = [], [], []

    if algo == "ddpg":
        key, k1, k2 = jax.random.split(key, 3)
        actor = M.init_policy(k1, spec, x, task, "actor")
        critic = M.init_policy(k2, spec, x, task, "critic")
        na, nc = actor.shape[0], critic.shape[0]
        zeros = lambda n: jnp.zeros((n,), jnp.float32)
        state = [
            _state_entry(b, run, "actor", actor),
            _state_entry(b, run, "critic", critic),
            _state_entry(b, run, "actor_t", actor),
            _state_entry(b, run, "critic_t", critic),
            _state_entry(b, run, "m_a", zeros(na)),
            _state_entry(b, run, "v_a", zeros(na)),
            _state_entry(b, run, "m_c", zeros(nc)),
            _state_entry(b, run, "v_c", zeros(nc)),
            _state_entry(b, run, "step", dtype="i32", shape=[]),
        ]
        batch = [
            ("obs", obs_bt), ("act", sds((bt, adim))), ("rew", sds((bt,))),
            ("nobs", obs_bt), ("done", sds((bt,))),
        ]
        metrics = rl.DDPG_METRICS
        update_fn = rl.ddpg_update(spec, task, x)
        act_arts = {
            "act": (rl.ddpg_act(spec, task, x),
                    [("actor", sds((na,))), ("obs", obs_b1)],
                    [("act", sds((1, adim)))]),
            "act_det": (rl.ddpg_act(spec, task, x),
                        [("actor", sds((na,))), ("obs", obs_b1)],
                        [("act", sds((1, adim)))]),
        }
    elif algo == "sac":
        key, k1, k2, k3 = jax.random.split(key, 4)
        actor = M.init_policy(k1, spec, x, task, "sac_actor")
        critics = jnp.concatenate(
            [
                M.init_policy(k2, spec, x, task, "critic"),
                M.init_policy(k3, spec, x, task, "critic"),
            ]
        )
        na, nc = actor.shape[0], critics.shape[0]
        zeros = lambda n: jnp.zeros((n,), jnp.float32)
        state = [
            _state_entry(b, run, "actor", actor),
            _state_entry(b, run, "critics", critics),
            _state_entry(b, run, "critics_t", critics),
            _state_entry(b, run, "log_alpha", jnp.zeros((1,), jnp.float32)),
            _state_entry(b, run, "m_a", zeros(na)),
            _state_entry(b, run, "v_a", zeros(na)),
            _state_entry(b, run, "m_c", zeros(nc)),
            _state_entry(b, run, "v_c", zeros(nc)),
            _state_entry(b, run, "m_al", zeros(1)),
            _state_entry(b, run, "v_al", zeros(1)),
            _state_entry(b, run, "step", dtype="i32", shape=[]),
        ]
        batch = [
            ("obs", obs_bt), ("act", sds((bt, adim))), ("rew", sds((bt,))),
            ("nobs", obs_bt), ("done", sds((bt,))),
            ("noise_next", sds((bt, adim))), ("noise_cur", sds((bt, adim))),
        ]
        metrics = rl.SAC_METRICS
        update_fn = rl.sac_update(spec, task, x)
        act_arts = {
            "act": (rl.sac_act(spec, task, x),
                    [("actor", sds((na,))), ("obs", obs_b1),
                     ("noise", sds((1, adim)))],
                    [("act", sds((1, adim)))]),
            "act_det": (rl.sac_act_det(spec, task, x),
                        [("actor", sds((na,))), ("obs", obs_b1)],
                        [("act", sds((1, adim)))]),
        }
    elif algo == "ppo":
        key, k1 = jax.random.split(key)
        params = M.init_policy(k1, spec, x, task, "ppo")
        npar = params.shape[0]
        zeros = lambda n: jnp.zeros((n,), jnp.float32)
        state = [
            _state_entry(b, run, "params", params),
            _state_entry(b, run, "m", zeros(npar)),
            _state_entry(b, run, "v", zeros(npar)),
            _state_entry(b, run, "step", dtype="i32", shape=[]),
        ]
        batch = [
            ("obs", obs_bt), ("act", sds((bt, adim))), ("old_logp", sds((bt,))),
            ("adv", sds((bt,))), ("ret", sds((bt,))),
        ]
        metrics = rl.PPO_METRICS
        update_fn = rl.ppo_update(spec, task, x)
        act_arts = {
            "act": (rl.ppo_act(spec, task, x),
                    [("params", sds((npar,))), ("obs", obs_b1),
                     ("noise", sds((1, adim)))],
                    [("act", sds((1, adim))), ("logp", sds((1,))),
                     ("value", sds((1,)))]),
            "act_det": (rl.ppo_act_det(spec, task, x),
                        [("params", sds((npar,))), ("obs", obs_b1)],
                        [("act", sds((1, adim))), ("value", sds((1,)))]),
        }
    else:
        raise ValueError(algo)

    batch_names = [n for n, _ in batch]
    update_name = f"{algo}_update_{run}_b{bt}"
    state_specs = [
        (s["name"], sds(tuple(s["shape"]),
                        jnp.int32 if s["dtype"] == "i32" else jnp.float32))
        for s in state
    ]
    out_specs = state_specs + [(m, sds(())) for m in metrics]
    b.artifact(
        update_name,
        update_fn,
        state_specs + batch,
        out_specs,
        {"kind": "update", "algo": algo, "task": task_name, "arch": arch, "batch": bt},
    )

    art_names = {"update": update_name}
    for role, (fn, ins, outs) in act_arts.items():
        name = f"{algo}_{role}_{run}_b1"
        b.artifact(name, fn, ins, outs,
                   {"kind": role, "algo": algo, "task": task_name, "arch": arch,
                    "batch": 1})
        art_names[role] = name

    b.manifest["trainstates"].append(
        {
            "name": run,
            "task": task_name,
            "algo": algo,
            "encoder": arch,
            "x": x,
            "batch": bt,
            "action_dim": adim,
            "max_action": task.max_action,
            "gamma": task.gamma,
            "episodes": task.episodes,
            "state": state,
            "batch_inputs": batch_names,
            "metrics": metrics,
            "artifacts": art_names,
        }
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="regex filter on artifact names")
    ap.add_argument("--list", action="store_true", help="list artifacts, build nothing")
    ap.add_argument("--skip-training", action="store_true")
    ap.add_argument("--skip-serving", action="store_true")
    args = ap.parse_args()

    b = Builder(args.out_dir, only=args.only, list_only=args.list)
    for name, spec in ENCODERS.items():
        b.manifest["encoders"][name] = {
            "serve": encoder_meta(spec, SERVE_CROP),
            "tiny": encoder_meta(spec, TINY_CROP),
        }

    if not args.skip_serving:
        print("— serving artifacts —")
        build_serving(b)
    if not args.skip_training:
        print("— training artifacts —")
        for task_name in TASKS:
            for arch in ENCODERS:
                build_training_combo(b, task_name, arch)
    b.finish()


if __name__ == "__main__":
    main()

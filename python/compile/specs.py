"""Shared architecture / task specifications for the MiniConv stack.

These constants are the single source of truth consumed by model.py, rl.py,
aot.py and (via artifacts/manifest.json) by the Rust coordinator. They mirror
the paper's setup (§3, §4.1):

  * observations are 3 stacked RGB frames -> 9 input channels, CHW float32
    in [0, 1] (SB3 ``normalize_images=True``);
  * MiniConv-K = three 3x3 stride-2 'same' conv + ReLU blocks, K output
    channels each => n = 3 stride-two layers, transmitted feature map
    K x ceil(X/8) x ceil(X/8);
  * Full-CNN = the SB3 NatureCNN baseline (8x8 s4 -> 4x4 s2 -> 3x3 s1,
    valid padding, + dense 512);
  * the server-side head projects flattened features to a 256-d vector and
    runs the algorithm-specific MLPs (DESIGN.md records this as the SB3
    ``features_dim`` analogue).

Scale note (DESIGN.md §2): training runs use a reduced "tiny" observation
(render 44 -> crop 36) so CPU-hosted runs finish; serving experiments use the
paper's render-100 -> crop-84 pipeline.
"""

from dataclasses import dataclass, field

OBS_CHANNELS = 9  # 3 stacked RGB frames
FRAME_STACK = 3
FEATURES_DIM = 256  # server-side projection width (SB3 features_dim analogue)

# Serving-scale observation (paper: render 100x100, centre-crop 84x84).
SERVE_RENDER = 100
SERVE_CROP = 84
# Tiny training-scale observation (substitution documented in DESIGN.md §2).
TINY_RENDER = 44
TINY_CROP = 36

BATCH_LADDER = [1, 2, 4, 8, 16, 32]
TRAIN_BATCH = 64


@dataclass(frozen=True)
class ConvLayer:
    cout: int
    k: int
    stride: int
    padding: str  # 'same' | 'valid'


@dataclass(frozen=True)
class EncoderSpec:
    name: str  # manifest tag: miniconv4 | miniconv16 | fullcnn
    kind: str  # 'miniconv' | 'fullcnn'
    layers: tuple
    dense: int | None  # trailing dense width (NatureCNN's 512), None for miniconv
    shader_deployable: bool

    def n_stride2(self) -> int:
        return sum(1 for l in self.layers if l.stride == 2)

    def feat_shape(self, x: int):
        """Spatial conv-output shape for square input x (channels, h, w)."""
        h = w = x
        c = OBS_CHANNELS
        for l in self.layers:
            if l.padding == "same":
                h = -(-h // l.stride)
                w = -(-w // l.stride)
            else:
                h = (h - l.k) // l.stride + 1
                w = (w - l.k) // l.stride + 1
            c = l.cout
        return (c, h, w)


def miniconv_spec(k: int) -> EncoderSpec:
    return EncoderSpec(
        name=f"miniconv{k}",
        kind="miniconv",
        layers=(
            ConvLayer(k, 3, 2, "same"),
            ConvLayer(k, 3, 2, "same"),
            ConvLayer(k, 3, 2, "same"),
        ),
        dense=None,
        shader_deployable=True,
    )


FULLCNN = EncoderSpec(
    name="fullcnn",
    kind="fullcnn",
    layers=(
        ConvLayer(32, 8, 4, "valid"),
        ConvLayer(64, 4, 2, "valid"),
        ConvLayer(64, 3, 1, "valid"),
    ),
    dense=512,
    shader_deployable=False,
)

MINICONV4 = miniconv_spec(4)
MINICONV16 = miniconv_spec(16)
ENCODERS = {e.name: e for e in (MINICONV4, MINICONV16, FULLCNN)}


@dataclass(frozen=True)
class TaskSpec:
    name: str
    algo: str  # ppo | sac | ddpg
    action_dim: int
    max_action: float
    episodes: int  # paper-scale episode budget (Tables 2-4)
    gamma: float = 0.99


TASKS = {
    "walker": TaskSpec("walker", "ppo", 6, 1.0, 2000),
    "hopper": TaskSpec("hopper", "sac", 3, 1.0, 2000),
    "pendulum": TaskSpec("pendulum", "ddpg", 1, 2.0, 1000),
}

# SB3-default hyperparameters used by rl.py (paper §4.1: defaults unless stated).
HYPERS = {
    "ddpg": dict(lr=1e-3, tau=0.005, gamma=0.99),
    "sac": dict(lr=3e-4, tau=0.005, gamma=0.99),
    "ppo": dict(lr=3e-4, clip=0.2, vf_coef=0.5, ent_coef=0.0, max_grad_norm=0.5),
}

"""L1: Pallas convolution / pooling / dense kernels, structured as shader passes.

The paper implements its MiniConv encoders as OpenGL *fragment-shader passes*:
each pass writes one RGBA texture (4 output channels), samples from at most
8 bound input textures (each holding 4 packed channels), and stays within a
64-texture-sample budget per shader invocation.

The TPU/Pallas translation (DESIGN.md §3) keeps that structure:

  * output channels are produced in blocks of 4  -> the pallas grid's
    ``ob`` dimension is exactly the paper's "pass index";
  * input channels are packed in blocks of 4     -> one "bound texture" per
    input block, and the per-pass working set (<= 8 blocks x H x W tile)
    is what must fit in VMEM;
  * kernel taps are fully unrolled python loops  -> the static sampling
    pattern of a fragment shader, with the per-tap contraction expressed as
    an einsum so the MXU (not the VPU) performs the MACs on real TPUs.

Gradients: ``pallas_call`` has no automatic VJP, so ``conv2d`` and ``dense``
carry custom VJPs whose backward passes are built from the *same* pallas
primitives (transposed/dilated convolutions and matmuls) — i.e. backprop is
shader-structured too, matching how the paper trains the encoder end-to-end
before exporting only the forward passes to GLSL.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the interpret path is the correctness (and AOT
lowering) vehicle. Real-TPU efficiency is estimated analytically in
DESIGN.md / EXPERIMENTS.md §Perf.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The CPU plugin can only run interpret-mode pallas; see module docstring.
INTERPRET = True

# Shader-model constants mirrored from the paper (Pi Zero 2 W deployment).
CHANNELS_PER_TEXTURE = 4  # RGBA packing
MAX_BOUND_TEXTURES = 8  # max input textures a fragment shader may sample
MAX_SAMPLES_PER_PASS = 64  # per-shader texture-sampling budget


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pass_samples(cin: int, kh: int, kw: int) -> int:
    """Texture samples one shader pass performs per output pixel."""
    return kh * kw * _ceil_div(cin, CHANNELS_PER_TEXTURE)


def pass_textures(cin: int) -> int:
    """Input textures a pass must bind (4 channels packed per texture)."""
    return _ceil_div(cin, CHANNELS_PER_TEXTURE)


def fits_shader_budget(cin: int, kh: int, kw: int) -> bool:
    """True when a conv layer's per-pass cost compiles to a legal shader."""
    return (
        pass_textures(cin) <= MAX_BOUND_TEXTURES
        and pass_samples(cin, kh, kw) <= MAX_SAMPLES_PER_PASS
    )


def _pad_axis_to(x, axis: int, multiple: int):
    size = x.shape[axis]
    target = _ceil_div(size, multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# Core valid convolution (pallas) + custom VJP
# ---------------------------------------------------------------------------


def _conv_pass_kernel(x_ref, w_ref, o_ref, *, stride, kh, kw, ho, wo):
    """One shader pass: 4 output channels over the full spatial block.

    The kernel gathers the kh·kw tap patches (the shader's static sampling
    pattern), stacks them, and performs a SINGLE im2col-style contraction —
    one big MXU matmul per pass instead of k² small ones. This keeps the
    lowered HLO compact (critical for AOT compile time; EXPERIMENTS.md
    §Perf) and is the efficient real-TPU mapping.

    x_ref: [B, Cin, H, W] (all bound "textures" for this pass)
    w_ref: [4, Cin, kh, kw] (this pass's filter taps)
    o_ref: [B, 4, Ho, Wo]
    """
    x = x_ref[...]  # [B, Cin, H, W] — the VMEM-resident working set
    patches = []
    for i in range(kh):
        for j in range(kw):
            patches.append(
                jax.lax.slice(
                    x,
                    (0, 0, i, j),
                    (
                        x.shape[0],
                        x.shape[1],
                        i + (ho - 1) * stride + 1,
                        j + (wo - 1) * stride + 1,
                    ),
                    (1, 1, stride, stride),
                )  # [B, Cin, Ho, Wo]
            )
    stacked = jnp.stack(patches, axis=1)  # [B, kh*kw, Cin, Ho, Wo]
    taps = w_ref[...].transpose(2, 3, 0, 1).reshape(kh * kw, CHANNELS_PER_TEXTURE, -1)
    # One contraction over (tap, cin): the MXU matmul of this pass.
    o_ref[...] = jnp.einsum(
        "toc,btchw->bohw", taps, stacked, preferred_element_type=jnp.float32
    )


def _conv_valid_raw(x, w, stride: int):
    """Valid conv via shader passes. x: [B,C,H,W], w: [O,C,kh,kw] -> [B,O,Ho,Wo]."""
    bsz, cin, h, wdt = x.shape
    cout, cin_w, kh, kw = w.shape
    assert cin == cin_w, f"channel mismatch {cin} vs {cin_w}"
    ho = (h - kh) // stride + 1
    wo = (wdt - kw) // stride + 1
    assert ho > 0 and wo > 0, f"conv output empty: {x.shape} w={w.shape} s={stride}"

    # RGBA-style packing: pad channel dims to multiples of 4.
    x = _pad_axis_to(x, 1, CHANNELS_PER_TEXTURE)
    w = _pad_axis_to(_pad_axis_to(w, 1, CHANNELS_PER_TEXTURE), 0, CHANNELS_PER_TEXTURE)
    cin_p = x.shape[1]
    cout_p = w.shape[0]
    n_passes = cout_p // CHANNELS_PER_TEXTURE

    out = pl.pallas_call(
        partial(_conv_pass_kernel, stride=stride, kh=kh, kw=kw, ho=ho, wo=wo),
        grid=(n_passes,),
        in_specs=[
            pl.BlockSpec((bsz, cin_p, x.shape[2], x.shape[3]), lambda ob: (0, 0, 0, 0)),
            pl.BlockSpec((CHANNELS_PER_TEXTURE, cin_p, kh, kw), lambda ob: (ob, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (bsz, CHANNELS_PER_TEXTURE, ho, wo), lambda ob: (0, ob, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, cout_p, ho, wo), jnp.float32),
        interpret=INTERPRET,
    )(x, w)
    return out[:, :cout]


def _dilate_hw(g, stride: int, extra_h: int, extra_w: int):
    """Insert stride-1 zeros between spatial elements, plus trailing zeros."""
    if stride == 1 and extra_h == 0 and extra_w == 0:
        return g
    b, c, h, w = g.shape
    hd = (h - 1) * stride + 1 + extra_h
    wd = (w - 1) * stride + 1 + extra_w
    out = jnp.zeros((b, c, hd, wd), g.dtype)
    return out.at[
        :, :, 0 : (h - 1) * stride + 1 : stride, 0 : (w - 1) * stride + 1 : stride
    ].set(g)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def conv_valid(stride: int, x, w):
    return _conv_valid_raw(x, w, stride)


def _conv_valid_fwd(stride, x, w):
    return _conv_valid_raw(x, w, stride), (x, w)


def _conv_valid_bwd(stride, res, g):
    x, w = res
    _, _, h, wdt = x.shape
    cout, cin, kh, kw = w.shape
    rh = (h - kh) % stride
    rw = (wdt - kw) % stride

    # dL/dx: full correlation of the (dilated) cotangent with the flipped,
    # transposed kernel — itself a stride-1 shader-pass conv.
    gd = _dilate_hw(g, stride, rh, rw)  # [B, O, H-kh+1, W-kw+1]
    gd_pad = jnp.pad(gd, ((0, 0), (0, 0), (kh - 1, kh - 1), (kw - 1, kw - 1)))
    w_flip = jnp.flip(w, axis=(2, 3)).transpose(1, 0, 2, 3)  # [C, O, kh, kw]
    dx = _conv_valid_raw(gd_pad, w_flip, 1)

    # dL/dw: correlate inputs with the dilated cotangent, batch as channels.
    xt = x.transpose(1, 0, 2, 3)  # [C, B, H, W]
    gt = _dilate_hw(g, stride, 0, 0).transpose(1, 0, 2, 3)  # [O, B, Hd, Wd]
    dw_full = _conv_valid_raw(xt, gt, 1)  # [C, O, kh+rh, kw+rw]
    dw = dw_full[:, :, :kh, :kw].transpose(1, 0, 2, 3)
    return dx, dw


conv_valid.defvjp(_conv_valid_fwd, _conv_valid_bwd)


def conv2d(x, w, b, *, stride: int = 1, padding: str = "valid"):
    """Shader-pass-structured, differentiable 2-D convolution.

    x: [B, Cin, H, W] float32; w: [Cout, Cin, kh, kw]; b: [Cout].
    padding: 'valid' or 'same' (same => output is ceil(H/stride)).
    Returns [B, Cout, Ho, Wo].
    """
    _, _, h, wdt = x.shape
    _, _, kh, kw = w.shape
    if padding == "same":
        ho = _ceil_div(h, stride)
        wo = _ceil_div(wdt, stride)
        pad_h = max((ho - 1) * stride + kh - h, 0)
        pad_w = max((wo - 1) * stride + kw - wdt, 0)
        x = jnp.pad(
            x,
            (
                (0, 0),
                (0, 0),
                (pad_h // 2, pad_h - pad_h // 2),
                (pad_w // 2, pad_w - pad_w // 2),
            ),
        )
    elif padding != "valid":
        raise ValueError(f"unknown padding {padding!r}")
    out = conv_valid(stride, x, w)
    return out + b[None, :, None, None]


# ---------------------------------------------------------------------------
# Max pooling (forward-only: used by the shader library, not inside any
# trained network, so no VJP is required — asserted in tests).
# ---------------------------------------------------------------------------


def _maxpool_pass_kernel(x_ref, o_ref, *, k, stride, ho, wo):
    """One pooling pass over a 4-channel block. x_ref: [B,4,H,W]."""
    x = x_ref[...]
    acc = jnp.full((x.shape[0], CHANNELS_PER_TEXTURE, ho, wo), -jnp.inf, jnp.float32)
    for i in range(k):
        for j in range(k):
            patch = jax.lax.slice(
                x,
                (0, 0, i, j),
                (
                    x.shape[0],
                    x.shape[1],
                    i + (ho - 1) * stride + 1,
                    j + (wo - 1) * stride + 1,
                ),
                (1, 1, stride, stride),
            )
            acc = jnp.maximum(acc, patch)
    o_ref[...] = acc


def maxpool2d(x, *, k: int = 2, stride: int | None = None):
    """Shader-pass max pooling. x: [B, C, H, W] -> [B, C, Ho, Wo] (valid)."""
    stride = stride or k
    bsz, c, h, wdt = x.shape
    ho = (h - k) // stride + 1
    wo = (wdt - k) // stride + 1
    x = _pad_axis_to(x, 1, CHANNELS_PER_TEXTURE)
    c_p = x.shape[1]

    out = pl.pallas_call(
        partial(_maxpool_pass_kernel, k=k, stride=stride, ho=ho, wo=wo),
        grid=(c_p // CHANNELS_PER_TEXTURE,),
        in_specs=[
            pl.BlockSpec((bsz, CHANNELS_PER_TEXTURE, h, wdt), lambda cb: (0, cb, 0, 0))
        ],
        out_specs=pl.BlockSpec(
            (bsz, CHANNELS_PER_TEXTURE, ho, wo), lambda cb: (0, cb, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, c_p, ho, wo), jnp.float32),
        interpret=INTERPRET,
    )(x)
    return out[:, :c]


# ---------------------------------------------------------------------------
# Dense layers: output dimension tiled so each program's weight block is a
# bounded VMEM slab (the MXU-facing analogue of the per-pass budget).
# ---------------------------------------------------------------------------

DENSE_TILE = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    # x_ref: [B, In]; w_ref: [In, T]; o_ref: [B, T]
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _matmul_raw(x, w):
    bsz, din = x.shape
    din_w, dout = w.shape
    assert din == din_w, f"matmul dim mismatch {din} vs {din_w}"
    w = _pad_axis_to(w, 1, DENSE_TILE)
    dout_p = w.shape[1]

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(dout_p // DENSE_TILE,),
        in_specs=[
            pl.BlockSpec((bsz, din), lambda t: (0, 0)),
            pl.BlockSpec((din, DENSE_TILE), lambda t: (0, t)),
        ],
        out_specs=pl.BlockSpec((bsz, DENSE_TILE), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((bsz, dout_p), jnp.float32),
        interpret=INTERPRET,
    )(x, w)
    return out[:, :dout]


@jax.custom_vjp
def matmul(x, w):
    return _matmul_raw(x, w)


def _matmul_fwd(x, w):
    return _matmul_raw(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    return _matmul_raw(g, w.T), _matmul_raw(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def dense(x, w, b):
    """Pallas dense layer. x: [B, In], w: [In, Out], b: [Out] -> [B, Out]."""
    return matmul(x, w) + b[None, :]

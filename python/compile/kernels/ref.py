"""Pure-jnp oracle implementations for the Pallas kernels.

Every kernel in ``conv.py`` must match these references to float32
tolerance; ``python/tests/test_kernel.py`` sweeps shapes with hypothesis.
"""

import jax.numpy as jnp
from jax import lax


def conv2d_ref(x, w, b, *, stride: int = 1, padding: str = "valid"):
    """Reference conv via lax.conv_general_dilated. Shapes as conv.conv2d."""
    if padding == "same":
        pad = "SAME"
    elif padding == "valid":
        pad = "VALID"
    else:
        raise ValueError(padding)
    out = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=pad,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def maxpool2d_ref(x, *, k: int = 2, stride: int | None = None):
    stride = stride or k
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 1, k, k),
        window_strides=(1, 1, stride, stride),
        padding="VALID",
    )


def dense_ref(x, w, b):
    return x @ w + b[None, :]

"""L2: JAX model definitions — encoders, heads, flat-parameter plumbing.

Everything here is *build-time only*: functions are jitted, lowered to HLO
text by aot.py and executed from Rust. To keep the Rust side shape-generic,
every network's parameters travel as a **single flat float32 vector**; the
(name, shape) template lives here and offsets are static at trace time.

Split-policy partitioning (paper §3): a policy is composed of
  * ``enc``  — the on-device part (MiniConv conv stack), whose output is the
               transmitted K-channel feature tensor;
  * ``head`` — the server-side part (flatten -> 256-d projection -> algorithm
               MLPs).
For the Full-CNN baseline there is no split: the whole stack is server-side.
"""

import math

import jax
import jax.numpy as jnp

from .kernels import conv as K
from .specs import FEATURES_DIM, OBS_CHANNELS, EncoderSpec, TaskSpec

# ---------------------------------------------------------------------------
# Parameter templates: ordered list of (name, shape). Flattened in order.
# ---------------------------------------------------------------------------


def template_size(template) -> int:
    return sum(math.prod(s) for _, s in template)


def pack(params) -> jnp.ndarray:
    """Concatenate a list of arrays into one flat f32 vector."""
    return jnp.concatenate([p.reshape(-1).astype(jnp.float32) for p in params])


def unpack(flat, template):
    """Split a flat vector back into arrays per the template (static offsets)."""
    out = []
    off = 0
    for _, shape in template:
        n = math.prod(shape)
        out.append(flat[off : off + n].reshape(shape))
        off += n
    assert off == flat.shape[0], f"template/flat mismatch: {off} vs {flat.shape[0]}"
    return out


def _orthogonal(key, shape, scale):
    """Orthogonal init (SB3 default for PPO; well-behaved everywhere)."""
    n_rows = shape[0]
    n_cols = math.prod(shape[1:])
    mat = jax.random.normal(key, (max(n_rows, n_cols), min(n_rows, n_cols)))
    q, r = jnp.linalg.qr(mat)
    q = q * jnp.sign(jnp.diag(r))[None, :]
    if n_rows < n_cols:
        q = q.T
    return (scale * q[:n_rows, :n_cols]).reshape(shape).astype(jnp.float32)


def init_params(key, template, out_scale: float = 0.01):
    """Initialise a template. Names ending in ``_out`` get a small gain."""
    params = []
    for name, shape in template:
        key, sub = jax.random.split(key)
        if name.endswith(".b"):
            params.append(jnp.zeros(shape, jnp.float32))
        elif name.endswith("log_std"):
            params.append(jnp.zeros(shape, jnp.float32))
        elif len(shape) == 1:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            scale = out_scale if "_out" in name else math.sqrt(2.0)
            params.append(_orthogonal(sub, shape, scale))
    return params


# ---------------------------------------------------------------------------
# Encoder (on-device part for MiniConv; full conv stack for Full-CNN)
# ---------------------------------------------------------------------------


def enc_template(spec: EncoderSpec, x: int):
    t = []
    cin = OBS_CHANNELS
    for i, l in enumerate(spec.layers):
        t.append((f"conv{i}.w", (l.cout, cin, l.k, l.k)))
        t.append((f"conv{i}.b", (l.cout,)))
        cin = l.cout
    if spec.dense is not None:
        c, h, w = spec.feat_shape(x)
        t.append(("dense.w", (c * h * w, spec.dense)))
        t.append(("dense.b", (spec.dense,)))
    return t


def enc_apply(spec: EncoderSpec, flat, obs):
    """obs: [B, 9, X, X] float32 in [0,1] -> transmitted features.

    MiniConv: [B, K, ceil(X/8), ceil(X/8)] conv map (what goes on the wire).
    Full-CNN: [B, 512] dense features (never transmitted; server-side).
    """
    tmpl = enc_template(spec, obs.shape[-1])
    p = unpack(flat, tmpl)
    x = obs
    i = 0
    for l in spec.layers:
        w, b = p[i], p[i + 1]
        i += 2
        x = jax.nn.relu(K.conv2d(x, w, b, stride=l.stride, padding=l.padding))
    if spec.dense is not None:
        w, b = p[i], p[i + 1]
        x = jax.nn.relu(K.dense(x.reshape(x.shape[0], -1), w, b))
    return x


def enc_out_dim(spec: EncoderSpec, x: int) -> int:
    if spec.dense is not None:
        return spec.dense
    c, h, w = spec.feat_shape(x)
    return c * h * w


# ---------------------------------------------------------------------------
# Server-side heads. All heads start with a 256-d projection of the
# (flattened) encoder output, then run algorithm-specific MLPs.
# ---------------------------------------------------------------------------


def proj_template(spec: EncoderSpec, x: int):
    return [
        ("proj.w", (enc_out_dim(spec, x), FEATURES_DIM)),
        ("proj.b", (FEATURES_DIM,)),
    ]


def proj_apply(flat_slice, feat):
    w, b = flat_slice
    return jax.nn.relu(K.dense(feat.reshape(feat.shape[0], -1), w, b))


def _mlp_template(prefix, dims):
    t = []
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        tag = "_out" if i == len(dims) - 2 else ""
        t.append((f"{prefix}.l{i}{tag}.w", (din, dout)))
        t.append((f"{prefix}.l{i}{tag}.b", (dout,)))
    return t


def _mlp_apply(params, x, *, final_act=None):
    n = len(params) // 2
    for i in range(n):
        w, b = params[2 * i], params[2 * i + 1]
        x = K.dense(x, w, b)
        if i < n - 1:
            x = jax.nn.relu(x)
    if final_act is not None:
        x = final_act(x)
    return x


# --- deterministic actor (DDPG) -------------------------------------------


def actor_head_template(spec: EncoderSpec, x: int, task: TaskSpec):
    return proj_template(spec, x) + _mlp_template(
        "actor", [FEATURES_DIM, 256, 256, task.action_dim]
    )


def actor_head_apply(task: TaskSpec, params, feat):
    h = proj_apply(params[:2], feat)
    a = _mlp_apply(params[2:], h, final_act=jnp.tanh)
    return a * task.max_action


# --- gaussian actor (SAC) ---------------------------------------------------

LOG_STD_MIN, LOG_STD_MAX = -20.0, 2.0


def sac_actor_head_template(spec: EncoderSpec, x: int, task: TaskSpec):
    return (
        proj_template(spec, x)
        + _mlp_template("trunk", [FEATURES_DIM, 256, 256])
        + [
            ("mu_out.w", (256, task.action_dim)),
            ("mu_out.b", (task.action_dim,)),
            ("logstd_out.w", (256, task.action_dim)),
            ("logstd_out.b", (task.action_dim,)),
        ]
    )


def sac_actor_dist(task: TaskSpec, params, feat):
    h = proj_apply(params[:2], feat)
    h = _mlp_apply(params[2:6], h, final_act=jax.nn.relu)
    mu = K.dense(h, params[6], params[7])
    log_std = jnp.clip(K.dense(h, params[8], params[9]), LOG_STD_MIN, LOG_STD_MAX)
    return mu, log_std


def squash(task: TaskSpec, mu, log_std, noise):
    """Reparameterised tanh-gaussian sample + log-prob (SB3 SquashedDiagGaussian)."""
    std = jnp.exp(log_std)
    pre = mu + std * noise
    act = jnp.tanh(pre)
    logp = -0.5 * (noise**2 + 2 * log_std + math.log(2 * math.pi)).sum(-1)
    # tanh correction
    logp -= jnp.log(jnp.clip(1 - act**2, 1e-6, None)).sum(-1)
    return act * task.max_action, logp


# --- PPO actor-critic -------------------------------------------------------


def ppo_head_template(spec: EncoderSpec, x: int, task: TaskSpec):
    return (
        proj_template(spec, x)
        + _mlp_template("pi", [FEATURES_DIM, task.action_dim])
        + _mlp_template("vf", [FEATURES_DIM, 1])
        + [("log_std", (task.action_dim,))]
    )


def ppo_head_apply(task: TaskSpec, params, feat):
    h = proj_apply(params[:2], feat)
    mu = _mlp_apply(params[2:4], h)
    value = _mlp_apply(params[4:6], h)[:, 0]
    log_std = params[6]
    return mu, log_std, value


def gaussian_logp(mu, log_std, act):
    std = jnp.exp(log_std)
    return -0.5 * (((act - mu) / std) ** 2 + 2 * log_std + math.log(2 * math.pi)).sum(
        -1
    )


# --- Q critic (DDPG/SAC) ----------------------------------------------------


def critic_head_template(spec: EncoderSpec, x: int, task: TaskSpec):
    return proj_template(spec, x) + _mlp_template(
        "qf", [FEATURES_DIM + task.action_dim, 256, 256, 1]
    )


def critic_head_apply(params, feat, act):
    h = proj_apply(params[:2], feat)
    q = _mlp_apply(params[2:], jnp.concatenate([h, act], axis=-1))
    return q[:, 0]


# ---------------------------------------------------------------------------
# Full policies = encoder + head over a flat (enc ++ head) vector.
# ---------------------------------------------------------------------------


def policy_templates(spec: EncoderSpec, x: int, task: TaskSpec, role: str):
    """(enc_template, head_template) for a role in {actor, sac_actor, ppo, critic}."""
    heads = {
        "actor": actor_head_template,
        "sac_actor": sac_actor_head_template,
        "ppo": ppo_head_template,
        "critic": critic_head_template,
    }
    return enc_template(spec, x), heads[role](spec, x, task)


def split_flat(flat, enc_tmpl, head_tmpl):
    ne = template_size(enc_tmpl)
    nh = template_size(head_tmpl)
    assert flat.shape[0] == ne + nh
    return flat[:ne], flat[ne:]


def actor_apply(spec, task, x, flat, obs):
    """Deterministic actor (DDPG) over flat enc++head params."""
    et, ht = policy_templates(spec, x, task, "actor")
    ef, hf = split_flat(flat, et, ht)
    feat = enc_apply(spec, ef, obs)
    return actor_head_apply(task, unpack(hf, ht), feat)


def sac_actor_apply(spec, task, x, flat, obs):
    et, ht = policy_templates(spec, x, task, "sac_actor")
    ef, hf = split_flat(flat, et, ht)
    feat = enc_apply(spec, ef, obs)
    return sac_actor_dist(task, unpack(hf, ht), feat)


def ppo_apply(spec, task, x, flat, obs):
    et, ht = policy_templates(spec, x, task, "ppo")
    ef, hf = split_flat(flat, et, ht)
    feat = enc_apply(spec, ef, obs)
    return ppo_head_apply(task, unpack(hf, ht), feat)


def critic_apply(spec, task, x, flat, obs, act):
    et, ht = policy_templates(spec, x, task, "critic")
    ef, hf = split_flat(flat, et, ht)
    feat = enc_apply(spec, ef, obs)
    return critic_head_apply(unpack(hf, ht), feat, act)


def init_policy(key, spec, x, task, role, out_scale=0.01):
    et, ht = policy_templates(spec, x, task, role)
    k1, k2 = jax.random.split(key)
    return pack(init_params(k1, et) + init_params(k2, ht, out_scale=out_scale))

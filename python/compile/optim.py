"""Adam optimiser over flat parameter vectors, written in plain jnp.

optax is unavailable in the build image, so the SB3-default optimiser is
reimplemented here. Operating on flat vectors keeps the AOT interface with
the Rust trainer to three tensors (params, m, v) + an int32 step counter.
"""

import jax.numpy as jnp


def adam_init(n: int):
    return jnp.zeros((n,), jnp.float32), jnp.zeros((n,), jnp.float32)


def adam_update(grad, params, m, v, step, *, lr, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step. ``step`` is the 1-based int32 step counter.

    Returns (params', m', v').
    """
    t = step.astype(jnp.float32)
    m = b1 * m + (1 - b1) * grad
    v = b2 * v + (1 - b2) * grad * grad
    mhat = m / (1 - b1**t)
    vhat = v / (1 - b2**t)
    return params - lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def clip_global_norm(grad, max_norm: float):
    """SB3 PPO's max_grad_norm clipping over the flat gradient."""
    norm = jnp.sqrt(jnp.sum(grad * grad))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return grad * scale, norm


def polyak(target, online, tau: float):
    """Soft target update used by DDPG/SAC (SB3 tau=0.005)."""
    return (1.0 - tau) * target + tau * online

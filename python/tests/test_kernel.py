"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/strides/paddings; every case asserts allclose.
This is the core correctness signal for the compute layer — the same
kernels are lowered into every serving/training artifact.
"""

import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as K
from compile.kernels import ref as R

RNG = np.random.default_rng(1234)


def t(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


def assert_close(a, b, rtol=2e-4, atol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# conv2d forward
# ---------------------------------------------------------------------------


conv_cases = st.tuples(
    st.integers(1, 3),  # batch
    st.integers(1, 10),  # cin
    st.integers(7, 24),  # h
    st.integers(7, 24),  # w
    st.integers(1, 9),  # cout
    st.sampled_from([1, 3, 5]),  # kernel
    st.sampled_from([1, 2, 3]),  # stride
    st.sampled_from(["same", "valid"]),
)


@settings(max_examples=40, deadline=None)
@given(conv_cases)
def test_conv2d_matches_ref(case):
    b, cin, h, w, cout, k, s, pad = case
    if pad == "valid" and (h < k or w < k):
        return
    x, wgt, bias = t(b, cin, h, w), t(cout, cin, k, k), t(cout)
    got = K.conv2d(x, wgt, bias, stride=s, padding=pad)
    want = R.conv2d_ref(x, wgt, bias, stride=s, padding=pad)
    assert got.shape == want.shape
    assert_close(got, want)


@pytest.mark.parametrize(
    "shape",
    [
        # the exact layer shapes used by the paper's encoders
        (1, 9, 84, 84, 4, 3, 2, "same"),  # MiniConv-4 layer 1, serve scale
        (1, 4, 42, 42, 4, 3, 2, "same"),  # MiniConv-4 layer 2
        (1, 16, 21, 21, 16, 3, 2, "same"),  # MiniConv-16 layer 3
        (2, 9, 36, 36, 32, 8, 4, "valid"),  # NatureCNN conv1, tiny scale
        (2, 32, 8, 8, 64, 4, 2, "valid"),  # NatureCNN conv2
        (2, 64, 3, 3, 64, 3, 1, "valid"),  # NatureCNN conv3
    ],
)
def test_conv2d_paper_shapes(shape):
    b, cin, h, w, cout, k, s, pad = shape
    x, wgt, bias = t(b, cin, h, w), t(cout, cin, k, k), t(cout)
    assert_close(
        K.conv2d(x, wgt, bias, stride=s, padding=pad),
        R.conv2d_ref(x, wgt, bias, stride=s, padding=pad),
    )


def test_conv2d_same_output_is_ceil():
    x, wgt, bias = t(1, 9, 85, 85), t(4, 9, 3, 3), t(4)
    out = K.conv2d(x, wgt, bias, stride=2, padding="same")
    assert out.shape == (1, 4, 43, 43)  # ceil(85/2)


def test_conv2d_rejects_channel_mismatch():
    with pytest.raises(AssertionError):
        K.conv2d(t(1, 3, 8, 8), t(4, 5, 3, 3), t(4))


# ---------------------------------------------------------------------------
# conv2d gradients (custom VJP vs autodiff of the reference)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,pad,h", [(1, "valid", 10), (2, "same", 17), (2, "valid", 12), (4, "valid", 36), (3, "same", 14)])
def test_conv2d_grads_match_ref(s, pad, h):
    cin, cout, k = 9, 8, 3 if s != 4 else 8
    x, wgt, bias = t(2, cin, h, h), t(cout, cin, k, k), t(cout)

    def lp(x, w, b):
        return jnp.sum(K.conv2d(x, w, b, stride=s, padding=pad) ** 2)

    def lr(x, w, b):
        return jnp.sum(R.conv2d_ref(x, w, b, stride=s, padding=pad) ** 2)

    gp = jax.grad(lp, argnums=(0, 1, 2))(x, wgt, bias)
    gr = jax.grad(lr, argnums=(0, 1, 2))(x, wgt, bias)
    for a, b_ in zip(gp, gr):
        assert_close(a, b_, rtol=2e-3, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 2),
    st.integers(1, 6),
    st.integers(8, 16),
    st.integers(1, 6),
    st.sampled_from([1, 2]),
)
def test_conv2d_grad_sweep(b, cin, h, cout, s):
    x, wgt, bias = t(b, cin, h, h), t(cout, cin, 3, 3), t(cout)

    def lp(args):
        return jnp.sum(jnp.sin(K.conv2d(args[0], args[1], args[2], stride=s, padding="same")))

    def lr(args):
        return jnp.sum(jnp.sin(R.conv2d_ref(args[0], args[1], args[2], stride=s, padding="same")))

    gp = jax.grad(lp)((x, wgt, bias))
    gr = jax.grad(lr)((x, wgt, bias))
    for a, b_ in zip(gp, gr):
        assert_close(a, b_, rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# maxpool
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 3),
    st.integers(1, 9),
    st.integers(6, 20),
    st.sampled_from([2, 3]),
    st.sampled_from([None, 1, 2]),
)
def test_maxpool_matches_ref(b, c, h, k, s):
    x = t(b, c, h, h)
    got = K.maxpool2d(x, k=k, stride=s)
    want = R.maxpool2d_ref(x, k=k, stride=s)
    assert got.shape == want.shape
    assert_close(got, want, rtol=0, atol=0)


def test_maxpool_padding_channels_not_leaked():
    # channel-padding inside the kernel must never leak the -inf/0 pad values
    x = -jnp.ones((1, 5, 6, 6), jnp.float32)  # all negative, 5 -> pads to 8
    out = K.maxpool2d(x, k=2)
    assert np.all(np.asarray(out) == -1.0)


# ---------------------------------------------------------------------------
# dense / matmul
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(1, 300), st.integers(1, 300))
def test_dense_matches_ref(b, din, dout):
    x, w, bias = t(b, din), t(din, dout), t(dout)
    assert_close(K.dense(x, w, bias), R.dense_ref(x, w, bias), rtol=1e-3, atol=1e-3)


def test_dense_grads():
    x, w, bias = t(4, 37), t(37, 130), t(130)
    gp = jax.grad(lambda x, w, b: jnp.sum(K.dense(x, w, b) ** 2), argnums=(0, 1, 2))(x, w, bias)
    gr = jax.grad(lambda x, w, b: jnp.sum(R.dense_ref(x, w, b) ** 2), argnums=(0, 1, 2))(x, w, bias)
    for a, b_ in zip(gp, gr):
        assert_close(a, b_, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# shader budget model
# ---------------------------------------------------------------------------


def test_shader_budget_miniconv_legal():
    # MiniConv layers must be deployable: <= 8 textures, <= 64 samples
    assert K.fits_shader_budget(9, 3, 3)  # layer 1: 3 textures, 27 samples
    assert K.fits_shader_budget(4, 3, 3)
    assert K.fits_shader_budget(16, 3, 3)  # 4 textures, 36 samples


def test_shader_budget_naturecnn_illegal():
    # NatureCNN conv1 (8x8 over 9 channels) blows the 64-sample budget:
    # that is *why* the paper's baseline cannot ship as shaders.
    assert not K.fits_shader_budget(9, 8, 8)


def test_pass_arithmetic():
    assert K.pass_textures(9) == 3
    assert K.pass_samples(9, 3, 3) == 27
    assert K.pass_textures(32) == 8
    assert K.pass_samples(64, 3, 3) == 16 * 9

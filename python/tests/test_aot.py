"""AOT pipeline tests: lowering produces parseable HLO text with the right
parameter/result signatures, and the manifest is internally consistent."""

import json
import os
import re

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot
from compile import model as M
from compile.specs import ENCODERS, MINICONV4, TASKS


def test_to_hlo_text_roundtrippable_signature():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # return_tuple=True: root computation returns a tuple
    assert re.search(r"ROOT .* tuple", text)
    assert text.count("parameter(0)") >= 1
    assert text.count("parameter(1)") >= 1


def test_to_hlo_text_pallas_kernel_lowers():
    from compile.kernels import conv as K

    def fn(x, w, b):
        return (K.conv2d(x, w, b, stride=2, padding="same"),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((1, 9, 12, 12), jnp.float32),
        jax.ShapeDtypeStruct((4, 9, 3, 3), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # interpret-mode pallas must lower to plain HLO: no mosaic custom-calls
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_builder_writes_manifest_and_bins(tmp_path):
    b = aot.Builder(str(tmp_path))

    def fn(p, x):
        return (p[:4].reshape(2, 2) @ x,)

    b.artifact(
        "toy",
        fn,
        [("params", aot.sds((8,))), ("x", aot.sds((2, 2)))],
        [("y", aot.sds((2, 2)))],
        {"kind": "toy"},
    )
    b.params_bin("toy_params", jnp.arange(8, dtype=jnp.float32))
    b.finish()

    man = json.load(open(tmp_path / "manifest.json"))
    assert man["artifacts"][0]["name"] == "toy"
    assert man["artifacts"][0]["inputs"][0] == {
        "name": "params", "dtype": "f32", "shape": [8],
    }
    assert os.path.exists(tmp_path / "toy.hlo.txt")
    raw = np.fromfile(tmp_path / "toy_params.bin", dtype="<f4")
    np.testing.assert_array_equal(raw, np.arange(8, dtype=np.float32))


def test_builder_only_filter_skips_lowering(tmp_path):
    b = aot.Builder(str(tmp_path), only="nomatch")
    called = []

    def fn(x):
        called.append(1)
        return (x,)

    b.artifact("skipme", fn, [("x", aot.sds((2,)))], [("y", aot.sds((2,)))], {})
    assert not os.path.exists(tmp_path / "skipme.hlo.txt")
    # manifest still records the artifact so the registry sees a stable set
    assert b.manifest["artifacts"][0]["name"] == "skipme"


def test_encoder_meta_layout_consistent():
    meta = aot.encoder_meta(MINICONV4, 84)
    total = sum(int(np.prod(p["shape"])) for p in meta["param_layout"])
    assert total == M.template_size(M.enc_template(MINICONV4, 84))
    assert meta["feat_shape"] == [4, 11, 11]  # ceil(84/8) = 11
    assert meta["n_stride2"] == 3
    assert meta["shader_deployable"] is True
    assert aot.encoder_meta(ENCODERS["fullcnn"], 36)["shader_deployable"] is False


def test_manifest_global_listing():
    b = aot.Builder("/tmp/unused_aot_dir", list_only=True)
    for name, spec in ENCODERS.items():
        b.manifest["encoders"][name] = {
            "serve": aot.encoder_meta(spec, 84),
            "tiny": aot.encoder_meta(spec, 36),
        }
    aot.build_serving(b)
    for t in TASKS:
        for a in ENCODERS:
            aot.build_training_combo(b, t, a)
    names = [a["name"] for a in b.manifest["artifacts"]]
    assert len(names) == len(set(names)), "duplicate artifact names"
    # one trainstate per (task, encoder)
    assert len(b.manifest["trainstates"]) == 9
    # every trainstate's artifacts exist in the artifact list
    for ts in b.manifest["trainstates"]:
        for art in ts["artifacts"].values():
            assert art in names
        # state tensors with files must reference recorded params
        pnames = {p["name"] for p in b.manifest["params"]}
        for s in ts["state"]:
            if "file" in s:
                assert s["file"].removesuffix(".bin") in pnames
    # serving ladder is complete
    for bb in [1, 2, 4, 8, 16, 32]:
        assert f"head_miniconv4_x84_b{bb}" in names
        assert f"full_fullcnn_x84_b{bb}" in names

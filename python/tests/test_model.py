"""L2 model tests: parameter plumbing, encoder shapes, head behaviours."""

import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile.specs import (
    ENCODERS,
    FEATURES_DIM,
    MINICONV4,
    MINICONV16,
    FULLCNN,
    OBS_CHANNELS,
    TASKS,
)

KEY = jax.random.PRNGKey(7)


def rand_obs(b, x):
    return jax.random.uniform(KEY, (b, OBS_CHANNELS, x, x), jnp.float32)


# ---------------------------------------------------------------------------
# flat-parameter plumbing
# ---------------------------------------------------------------------------


def test_pack_unpack_roundtrip():
    tmpl = [("a.w", (3, 4)), ("a.b", (4,)), ("z_out.w", (4, 2))]
    params = M.init_params(KEY, tmpl)
    flat = M.pack(params)
    assert flat.shape == (M.template_size(tmpl),)
    back = M.unpack(flat, tmpl)
    for p, q in zip(params, back):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(q))


def test_unpack_rejects_wrong_length():
    # too short -> reshape TypeError; too long -> our assertion
    with pytest.raises((AssertionError, TypeError)):
        M.unpack(jnp.zeros(11), [("w", (3, 4))])
    with pytest.raises(AssertionError):
        M.unpack(jnp.zeros(13), [("w", (3, 4))])


def test_orthogonal_init_is_orthogonal():
    w = M._orthogonal(KEY, (64, 64), 1.0)
    eye = np.asarray(w @ w.T)
    np.testing.assert_allclose(eye, np.eye(64), atol=1e-4)


def test_init_bias_zero_logstd_zero():
    tmpl = [("l.w", (8, 8)), ("l.b", (8,)), ("log_std", (3,))]
    p = M.init_params(KEY, tmpl)
    assert np.all(np.asarray(p[1]) == 0)
    assert np.all(np.asarray(p[2]) == 0)


# ---------------------------------------------------------------------------
# encoders
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("x", [36, 84])
@pytest.mark.parametrize("spec", [MINICONV4, MINICONV16])
def test_miniconv_feature_shape(spec, x):
    tmpl = M.enc_template(spec, x)
    flat = M.pack(M.init_params(KEY, tmpl))
    feat = M.enc_apply(spec, flat, rand_obs(2, x))
    s = math.ceil(x / 8)  # n = 3 stride-2 layers
    k = spec.layers[-1].cout
    assert feat.shape == (2, k, s, s)
    # transmitted bytes: K * (X/2^n)^2  — the paper's communication model
    assert feat.shape[1] * feat.shape[2] * feat.shape[3] == k * s * s


def test_fullcnn_feature_shape():
    tmpl = M.enc_template(FULLCNN, 36)
    flat = M.pack(M.init_params(KEY, tmpl))
    feat = M.enc_apply(FULLCNN, flat, rand_obs(1, 36))
    assert feat.shape == (1, 512)


def test_miniconv_n_stride2_is_3():
    assert MINICONV4.n_stride2() == 3
    assert MINICONV16.n_stride2() == 3


def test_encoder_outputs_nonnegative():
    # all encoders end in ReLU => transmitted features are >= 0, which is
    # what makes the uint8 wire quantisation well-posed
    for spec in (MINICONV4, MINICONV16):
        tmpl = M.enc_template(spec, 36)
        flat = M.pack(M.init_params(KEY, tmpl))
        feat = M.enc_apply(spec, flat, rand_obs(1, 36))
        assert float(feat.min()) >= 0.0


def test_enc_param_count_tiny():
    # MiniConv-4: (9*4*9+4) + (4*4*9+4) + (4*4*9+4) = 328 + 148 + 148
    assert M.template_size(M.enc_template(MINICONV4, 36)) == 328 + 148 + 148


# ---------------------------------------------------------------------------
# heads / policies
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["miniconv4", "miniconv16", "fullcnn"])
def test_ddpg_actor_bounded(arch):
    task = TASKS["pendulum"]
    spec = ENCODERS[arch]
    flat = M.init_policy(KEY, spec, 36, task, "actor")
    act = M.actor_apply(spec, task, 36, flat, rand_obs(3, 36))
    assert act.shape == (3, task.action_dim)
    assert float(jnp.abs(act).max()) <= task.max_action + 1e-6


def test_sac_actor_dist_shapes_and_bounds():
    task = TASKS["hopper"]
    flat = M.init_policy(KEY, MINICONV4, 36, task, "sac_actor")
    mu, log_std = M.sac_actor_apply(MINICONV4, task, 36, flat, rand_obs(2, 36))
    assert mu.shape == (2, 3) and log_std.shape == (2, 3)
    assert float(log_std.min()) >= M.LOG_STD_MIN
    assert float(log_std.max()) <= M.LOG_STD_MAX
    noise = jax.random.normal(KEY, (2, 3))
    act, logp = M.squash(task, mu, log_std, noise)
    assert act.shape == (2, 3) and logp.shape == (2,)
    assert float(jnp.abs(act).max()) <= task.max_action


def test_squash_logp_matches_change_of_variables():
    # for zero noise, act = tanh(mu): logp = N(mu|mu,std) - log(1-tanh^2)
    task = TASKS["hopper"]
    mu = jnp.array([[0.3, -0.2, 0.1]])
    log_std = jnp.zeros((1, 3))
    act, logp = M.squash(task, mu, log_std, jnp.zeros((1, 3)))
    base = -0.5 * 3 * math.log(2 * math.pi)
    corr = float(jnp.log(1 - jnp.tanh(mu) ** 2).sum())
    np.testing.assert_allclose(float(logp[0]), base - corr, rtol=1e-5)


def test_ppo_apply_shapes():
    task = TASKS["walker"]
    flat = M.init_policy(KEY, MINICONV16, 36, task, "ppo")
    mu, log_std, value = M.ppo_apply(MINICONV16, task, 36, flat, rand_obs(4, 36))
    assert mu.shape == (4, 6) and log_std.shape == (6,) and value.shape == (4,)


def test_gaussian_logp_matches_scipy_formula():
    mu = jnp.array([[0.0, 1.0]])
    log_std = jnp.array([[0.0, 0.5]])
    act = jnp.array([[0.5, 0.5]])
    got = float(M.gaussian_logp(mu, log_std, act)[0])
    want = sum(
        -0.5 * ((a - m) / math.exp(s)) ** 2 - s - 0.5 * math.log(2 * math.pi)
        for a, m, s in [(0.5, 0.0, 0.0), (0.5, 1.0, 0.5)]
    )
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_critic_scalar_output():
    task = TASKS["pendulum"]
    flat = M.init_policy(KEY, MINICONV4, 36, task, "critic")
    q = M.critic_apply(
        MINICONV4, task, 36, flat, rand_obs(5, 36), jnp.zeros((5, 1))
    )
    assert q.shape == (5,)


def test_split_flat_partition():
    task = TASKS["pendulum"]
    et, ht = M.policy_templates(MINICONV4, 84, task, "actor")
    flat = M.init_policy(KEY, MINICONV4, 84, task, "actor")
    ef, hf = M.split_flat(flat, et, ht)
    assert ef.shape[0] == M.template_size(et)
    assert hf.shape[0] == M.template_size(ht)
    # device/server partition must be lossless
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate([ef, hf])), np.asarray(flat)
    )


def test_split_policy_equals_full_policy():
    """enc -> head composition must equal the monolithic actor — the core
    split-policy invariant (paper §3)."""
    task = TASKS["pendulum"]
    x = 36
    et, ht = M.policy_templates(MINICONV4, x, task, "actor")
    flat = M.init_policy(KEY, MINICONV4, x, task, "actor")
    ef, hf = M.split_flat(flat, et, ht)
    obs = rand_obs(2, x)
    # monolithic
    a_full = M.actor_apply(MINICONV4, task, x, flat, obs)
    # split: device encode, then server head
    feat = M.enc_apply(MINICONV4, ef, obs)
    a_split = M.actor_head_apply(task, M.unpack(hf, ht), feat)
    np.testing.assert_allclose(np.asarray(a_full), np.asarray(a_split), rtol=1e-5, atol=1e-6)

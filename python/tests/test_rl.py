"""L2 RL tests: each update step must reduce its own loss / behave sanely
on synthetic batches, and Adam/polyak must match hand calculations."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile import optim as O
from compile import rl
from compile.specs import ENCODERS, MINICONV4, TASKS

KEY = jax.random.PRNGKey(3)
X = 12  # micro observation for fast tests (3 stride-2 layers still legal)
B = 8


def obs_batch(key, b=B):
    return jax.random.uniform(key, (b, 9, X, X), jnp.float32)


# ---------------------------------------------------------------------------
# optimiser
# ---------------------------------------------------------------------------


def test_adam_first_step_is_lr_signed():
    p = jnp.zeros(4)
    g = jnp.array([1.0, -1.0, 2.0, 0.0])
    m, v = O.adam_init(4)
    p2, m2, v2 = O.adam_update(g, p, m, v, jnp.int32(1), lr=0.1)
    # bias-corrected first step ~= -lr * sign(g)
    np.testing.assert_allclose(
        np.asarray(p2), [-0.1, 0.1, -0.1, 0.0], rtol=0, atol=1e-6
    )


def test_adam_converges_on_quadratic():
    p = jnp.array([5.0, -3.0])
    m, v = O.adam_init(2)
    for t in range(1, 400):
        g = 2 * p
        p, m, v = O.adam_update(g, p, m, v, jnp.int32(t), lr=0.05)
    assert float(jnp.abs(p).max()) < 1e-2


def test_clip_global_norm():
    g = jnp.array([3.0, 4.0])  # norm 5
    clipped, norm = O.clip_global_norm(g, 0.5)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(clipped), [0.3, 0.4], rtol=1e-5
    )
    same, _ = O.clip_global_norm(g, 50.0)
    np.testing.assert_allclose(np.asarray(same), np.asarray(g))


def test_polyak():
    t = jnp.zeros(3)
    o = jnp.ones(3)
    out = O.polyak(t, o, 0.005)
    np.testing.assert_allclose(np.asarray(out), 0.005 * np.ones(3), rtol=1e-6)


# ---------------------------------------------------------------------------
# DDPG
# ---------------------------------------------------------------------------


def ddpg_state(key, task):
    k1, k2 = jax.random.split(key)
    actor = M.init_policy(k1, MINICONV4, X, task, "actor")
    critic = M.init_policy(k2, MINICONV4, X, task, "critic")
    z = lambda n: jnp.zeros((n,), jnp.float32)
    return [actor, critic, actor, critic, z(actor.size), z(actor.size),
            z(critic.size), z(critic.size), jnp.int32(0)]


def ddpg_batch(key, task):
    ks = jax.random.split(key, 5)
    return [
        obs_batch(ks[0]),
        jax.random.uniform(ks[1], (B, task.action_dim), minval=-1.0, maxval=1.0),
        jax.random.normal(ks[2], (B,)),
        obs_batch(ks[3]),
        (jax.random.uniform(ks[4], (B,)) < 0.1).astype(jnp.float32),
    ]


def test_ddpg_update_shapes_and_step():
    task = TASKS["pendulum"]
    update = rl.ddpg_update(MINICONV4, task, X)
    st = ddpg_state(KEY, task)
    out = update(*st, *ddpg_batch(KEY, task))
    assert len(out) == len(st) + 2
    for a, b in zip(out[:8], st[:8]):
        assert a.shape == b.shape
    assert int(out[8]) == 1  # step incremented


def test_ddpg_critic_loss_decreases_on_fixed_batch():
    task = TASKS["pendulum"]
    update = jax.jit(rl.ddpg_update(MINICONV4, task, X))
    st = ddpg_state(KEY, task)
    batch = ddpg_batch(jax.random.PRNGKey(11), task)
    losses = []
    for _ in range(25):
        out = update(*st, *batch)
        st = list(out[:9])
        losses.append(float(out[9]))
    assert losses[-1] < losses[0] * 0.5, losses


def test_ddpg_targets_move_slowly():
    task = TASKS["pendulum"]
    update = rl.ddpg_update(MINICONV4, task, X)
    st = ddpg_state(KEY, task)
    out = update(*st, *ddpg_batch(KEY, task))
    # target nets move by at most tau * max-param-change
    dt = float(jnp.abs(out[2] - st[2]).max())
    da = float(jnp.abs(out[0] - st[0]).max())
    assert dt <= 0.005 * da + 1e-9


# ---------------------------------------------------------------------------
# SAC
# ---------------------------------------------------------------------------


def sac_state(key, task):
    k1, k2, k3 = jax.random.split(key, 3)
    actor = M.init_policy(k1, MINICONV4, X, task, "sac_actor")
    critics = jnp.concatenate([
        M.init_policy(k2, MINICONV4, X, task, "critic"),
        M.init_policy(k3, MINICONV4, X, task, "critic"),
    ])
    z = lambda n: jnp.zeros((n,), jnp.float32)
    return [actor, critics, critics, z(1), z(actor.size), z(actor.size),
            z(critics.size), z(critics.size), z(1), z(1), jnp.int32(0)]


def sac_batch(key, task):
    ks = jax.random.split(key, 7)
    a = task.action_dim
    return [
        obs_batch(ks[0]),
        jax.random.uniform(ks[1], (B, a), minval=-1.0, maxval=1.0),
        jax.random.normal(ks[2], (B,)),
        obs_batch(ks[3]),
        (jax.random.uniform(ks[4], (B,)) < 0.1).astype(jnp.float32),
        jax.random.normal(ks[5], (B, a)),
        jax.random.normal(ks[6], (B, a)),
    ]


def test_sac_update_shapes():
    task = TASKS["hopper"]
    update = rl.sac_update(MINICONV4, task, X)
    st = sac_state(KEY, task)
    out = update(*st, *sac_batch(KEY, task))
    assert len(out) == len(st) + 4
    assert int(out[10]) == 1
    alpha = float(out[-1])
    assert alpha > 0.0


def test_sac_critic_loss_decreases_on_fixed_targets():
    # with done=1 the TD target is just the reward (no bootstrapping, no
    # moving target net), so the critic loss must fall monotonically-ish
    task = TASKS["hopper"]
    update = jax.jit(rl.sac_update(MINICONV4, task, X))
    st = sac_state(KEY, task)
    batch = sac_batch(jax.random.PRNGKey(5), task)
    batch[4] = jnp.ones((B,))  # done = 1
    losses = []
    for _ in range(60):
        out = update(*st, *batch)
        st = list(out[:11])
        losses.append(float(out[11]))
    assert losses[-1] < losses[0] * 0.6, (losses[0], losses[-1])


def test_sac_twin_critics_independent():
    task = TASKS["hopper"]
    st = sac_state(KEY, task)
    critics = st[1]
    half = critics.shape[0] // 2
    q1, q2 = rl._twin_q(MINICONV4, task, X, critics, obs_batch(KEY), jnp.zeros((B, 3)))
    assert q1.shape == (B,) and q2.shape == (B,)
    # different init -> different estimates
    assert float(jnp.abs(q1 - q2).max()) > 1e-6


# ---------------------------------------------------------------------------
# PPO
# ---------------------------------------------------------------------------


def ppo_state(key, task):
    params = M.init_policy(key, MINICONV4, X, task, "ppo")
    z = lambda n: jnp.zeros((n,), jnp.float32)
    return [params, z(params.size), z(params.size), jnp.int32(0)]


def ppo_batch(key, task, params):
    ks = jax.random.split(key, 4)
    obs = obs_batch(ks[0])
    noise = jax.random.normal(ks[1], (B, task.action_dim))
    act_fn = rl.ppo_act(MINICONV4, task, X)
    act, logp, value = act_fn(params, obs, noise)
    adv = jax.random.normal(ks[2], (B,))
    ret = np.asarray(value) + np.asarray(adv)
    return [obs, act, logp, adv, jnp.asarray(ret)]


def test_ppo_update_shapes():
    task = TASKS["walker"]
    st = ppo_state(KEY, task)
    batch = ppo_batch(KEY, task, st[0])
    out = rl.ppo_update(MINICONV4, task, X)(*st, *batch)
    assert len(out) == 8
    assert out[0].shape == st[0].shape
    assert int(out[3]) == 1


def test_ppo_first_update_kl_near_zero():
    # on-policy batch sampled from the same params => ratio ~= 1, kl ~= 0
    task = TASKS["walker"]
    st = ppo_state(KEY, task)
    batch = ppo_batch(KEY, task, st[0])
    out = rl.ppo_update(MINICONV4, task, X)(*st, *batch)
    approx_kl = float(out[7])
    assert abs(approx_kl) < 1e-4


def test_ppo_value_loss_decreases():
    task = TASKS["walker"]
    update = jax.jit(rl.ppo_update(MINICONV4, task, X))
    st = ppo_state(KEY, task)
    batch = ppo_batch(jax.random.PRNGKey(2), task, st[0])
    v0 = None
    v_last = None
    # value-head progress under the clipped objective is slow initially;
    # 150 steps gives a clear (several-x) drop
    for i in range(150):
        out = update(*st, *batch)
        st = list(out[:4])
        if v0 is None:
            v0 = float(out[5])
        v_last = float(out[5])
    assert v_last < v0 * 0.5, (v0, v_last)


def test_ppo_act_logp_consistent():
    task = TASKS["walker"]
    st = ppo_state(KEY, task)
    obs = obs_batch(KEY, 2)
    noise = jnp.zeros((2, task.action_dim))
    act, logp, value = rl.ppo_act(MINICONV4, task, X)(st[0], obs, noise)
    mu, log_std, v2 = M.ppo_apply(MINICONV4, task, X, st[0], obs)
    np.testing.assert_allclose(np.asarray(act), np.asarray(mu), rtol=1e-5, atol=1e-6)
    want = M.gaussian_logp(mu, log_std[None, :], act)
    np.testing.assert_allclose(np.asarray(logp), np.asarray(want), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(value), np.asarray(v2), rtol=1e-5)


# ---------------------------------------------------------------------------
# act artifact functions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["miniconv4", "miniconv16"])
def test_ddpg_act_deterministic(arch):
    task = TASKS["pendulum"]
    spec = ENCODERS[arch]
    actor = M.init_policy(KEY, spec, X, task, "actor")
    fn = rl.ddpg_act(spec, task, X)
    obs = obs_batch(KEY, 1)
    a1 = fn(actor, obs)[0]
    a2 = fn(actor, obs)[0]
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_sac_act_respects_bounds_and_noise():
    task = TASKS["hopper"]
    actor = M.init_policy(KEY, MINICONV4, X, task, "sac_actor")
    obs = obs_batch(KEY, 1)
    fn = rl.sac_act(MINICONV4, task, X)
    a0 = fn(actor, obs, jnp.zeros((1, 3)))[0]
    a1 = fn(actor, obs, 2.0 * jnp.ones((1, 3)))[0]
    assert float(jnp.abs(a0).max()) <= task.max_action
    assert float(jnp.abs(a1).max()) <= task.max_action
    assert float(jnp.abs(a0 - a1).max()) > 1e-7  # noise changes the action
    det = rl.sac_act_det(MINICONV4, task, X)(actor, obs)[0]
    assert det.shape == (1, 3)

"""Cross-check for rust/src/shader/compiled.rs interior/border math.

Mirrors, in pure Python, the legacy interpreter's per-pixel conv/pool
(checked border-zero fetch at every tap) and the compiled pipeline's
interior/border split (interior pixels read unchecked), and asserts the
two produce identical outputs over a sweep of shapes. Run directly:

    python3 python/check_compiled_regions.py
"""

import random


def interior_axis(out_dim, in_dim, k, stride, pad):
    lo = -(-pad // stride)  # ceil div
    if in_dim + pad < k:
        return (0, 0)
    hi = min((in_dim + pad - k) // stride + 1, out_dim)
    return (0, 0) if lo >= hi else (lo, hi)


def conv_legacy(inp, in_h, in_w, out_h, out_w, k, stride, pad):
    out = [0.0] * (out_h * out_w)
    for oy in range(out_h):
        for ox in range(out_w):
            acc = 0.0
            iy0 = oy * stride - pad
            ix0 = ox * stride - pad
            for ky in range(k):
                for kx in range(k):
                    y, x = iy0 + ky, ix0 + kx
                    v = inp[y * in_w + x] if 0 <= y < in_h and 0 <= x < in_w else 0.0
                    acc += v * ((ky * k + kx) % 7 + 1)  # stand-in weights
            out[oy * out_w + ox] = acc
    return out


def conv_compiled(inp, in_h, in_w, out_h, out_w, k, stride, pad):
    out = [None] * (out_h * out_w)
    oy0, oy1 = interior_axis(out_h, in_h, k, stride, pad)
    ox0, ox1 = interior_axis(out_w, in_w, k, stride, pad)
    interior = oy0 < oy1 and ox0 < ox1
    top_end, bot_start = (oy0, oy1) if interior else (out_h, out_h)

    def border_px(oy, ox):
        acc = 0.0
        iy0 = oy * stride - pad
        ix0 = ox * stride - pad
        for ky in range(k):
            for kx in range(k):
                y, x = iy0 + ky, ix0 + kx
                v = inp[y * in_w + x] if 0 <= y < in_h and 0 <= x < in_w else 0.0
                acc += v * ((ky * k + kx) % 7 + 1)
        return acc

    for oy in list(range(top_end)) + list(range(bot_start, out_h)):
        for ox in range(out_w):
            out[oy * out_w + ox] = border_px(oy, ox)
    if interior:
        for oy in range(oy0, oy1):
            for ox in list(range(ox0)) + list(range(ox1, out_w)):
                out[oy * out_w + ox] = border_px(oy, ox)
        for oy in range(oy0, oy1):
            iy0 = oy * stride - pad
            assert iy0 >= 0, (oy, stride, pad)
            for ox in range(ox0, ox1):
                ix0 = ox * stride - pad
                assert ix0 >= 0
                acc = 0.0
                for ky in range(k):
                    row = iy0 + ky
                    assert row < in_h, (row, in_h, oy, k, stride, pad)
                    for kx in range(k):
                        col = ix0 + kx
                        assert col < in_w
                        acc += inp[row * in_w + col] * ((ky * k + kx) % 7 + 1)
                out[oy * out_w + ox] = acc
    assert all(v is not None for v in out), "pixel not covered exactly once"
    return out


def main():
    rng = random.Random(0)
    checked = 0
    for in_h in range(1, 30):
        in_w = in_h
        for k in (1, 2, 3, 4):
            for stride in (1, 2, 3):
                for same in (True, False):
                    if same:
                        out_h = -(-in_h // stride)
                        out_w = -(-in_w // stride)
                        pad = max((out_h - 1) * stride + k - in_h, 0) // 2
                    else:
                        if in_h < k:
                            continue
                        out_h = (in_h - k) // stride + 1
                        out_w = (in_w - k) // stride + 1
                        pad = 0
                    inp = [rng.uniform(-1, 1) for _ in range(in_h * in_w)]
                    a = conv_legacy(inp, in_h, in_w, out_h, out_w, k, stride, pad)
                    b = conv_compiled(inp, in_h, in_w, out_h, out_w, k, stride, pad)
                    assert a == b, (in_h, k, stride, same, pad)
                    checked += 1
    print(f"OK: {checked} shape/kernel/stride/pad combinations match exactly")


if __name__ == "__main__":
    main()

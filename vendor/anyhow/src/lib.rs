//! Minimal offline stand-in for the `anyhow` crate (DESIGN.md §1: crates.io
//! is unreachable in the build environment, so the few third-party surfaces
//! this repo relies on are vendored as small, purpose-built facades).
//!
//! Implements the subset the codebase uses: [`Error`] (a boxed message
//! chain), [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`. Like the real
//! crate, `Error` deliberately does not implement `std::error::Error`, which
//! is what lets the blanket `From<E: std::error::Error>` conversion and the
//! `Context` impl over `Result<T, Error>` coexist coherently.

use std::error::Error as StdError;
use std::fmt;

/// `Result` specialised to [`Error`], matching `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error: a message plus an optional chain of underlying causes
/// (outermost context first, original error innermost).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost context first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, like the real anyhow
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let chain = self.chain();
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // flatten the std source chain into our message chain
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        nest(msgs)
    }
}

/// Build a nested Error from messages ordered outermost-first.
fn nest(mut msgs: Vec<String>) -> Error {
    let mut err = Error { msg: msgs.pop().expect("nest of empty chain"), source: None };
    while let Some(m) = msgs.pop() {
        err = Error { msg: m, source: Some(Box::new(err)) };
    }
    err
}

/// Conversion into [`Error`] for both std errors and `Error` itself — the
/// same trick the real anyhow uses so `.context(..)` works on either.
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E: StdError + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: disk on fire");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().root_cause(), "disk on fire");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.chain(), vec!["outer", "disk on fire"]);

        let o: Option<u32> = None;
        let e = o.with_context(|| "missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");

        // context on an already-anyhow Result
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
    }
}

//! Offline stub of the `xla` PJRT bindings (DESIGN.md §1).
//!
//! The build environment has no PJRT/XLA native libraries, so this crate
//! provides the exact API surface `runtime::executor` compiles against.
//! Constructors succeed (so `Runtime::new` works and artifact-free paths —
//! the Sim serving backend, the shader interpreter, the analytic models —
//! run normally), while anything that would need a real compiler/device
//! returns a descriptive [`Error`]. Artifact-backed tests detect the missing
//! `artifacts/manifest.json` and skip, so the stub is never reached there.
//!
//! To run with real PJRT, point the `xla` entry in the workspace Cargo.toml
//! at the actual bindings; no source changes are required.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` as used by the runtime (Display only).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} requires the real PJRT bindings (this build vendors \
         the offline stub; see DESIGN.md §1)"
    ))
}

/// Element types the runtime moves across the boundary.
pub trait NativeType: Copy + fmt::Debug + 'static {}

impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side literal. The stub keeps no data — it only needs to typecheck
/// construction; decoding paths are unreachable without a real executable.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal
    }

    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("literal decode"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple decode"))
    }
}

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "parsing HLO text {}",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execution"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execution"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compilation"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_succeed_and_execution_fails_loudly() {
        let client = PjRtClient::cpu().unwrap();
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        let _ = Literal::scalar(3i32);
        let buf = client.buffer_from_host_buffer(&[1.0f32], &[1], None).unwrap();
        assert!(buf.to_literal_sync().is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let err = HloModuleProto::from_text_file("/nope.hlo").unwrap_err();
        assert!(err.to_string().contains("xla stub"), "{err}");
    }
}

//! Minimal offline stand-in for the `log` facade crate (DESIGN.md §1).
//!
//! Same shape as the real crate for the subset this repo uses: the five
//! level macros, the [`Log`] trait, [`set_logger`]/[`set_max_level`], and
//! the [`Record`]/[`Metadata`] types. Until a logger is installed and a max
//! level set, everything is a no-op — exactly the real facade's behaviour.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log verbosity, ordered `Error < Warn < Info < Debug < Trace`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Maximum-level filter: `Off` silences everything.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata about a log request (just the level here).
#[derive(Debug, Clone, Copy)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log event: level + preformatted arguments.
pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// Sink for log records, installed once per process.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _m: &Metadata) -> bool {
        false
    }
    fn log(&self, _r: &Record) {}
    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // Off

/// Returned when a logger is installed twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __log(level: Level, args: fmt::Arguments) {
    if level as usize <= max_level() as usize {
        let record = Record { metadata: Metadata { level }, args };
        let l = logger();
        if l.enabled(record.metadata()) {
            l.log(&record);
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Error, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Warn, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Info, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Debug, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Trace, format_args!($($arg)+)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_like_the_real_crate() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
        assert_eq!(Level::Warn.as_str(), "WARN");
    }

    #[test]
    fn macros_are_silent_without_a_logger() {
        // must not panic or print; max level defaults to Off
        info!("nothing to see {}", 1);
        warn!("still nothing");
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
